//! The engine core: **one** implementation of Alg 4's
//! claim → evaluate → publish → broadcast protocol, plus the two drivers
//! that schedule it:
//!
//! * [`run_threaded`] — real OS threads, wall-clock time, any
//!   [`Transport`]. One worker per [`WorkerSlot`]; workers of a rank
//!   share that rank's [`SharedState`]; bound movements travel as
//!   BroadcastK messages. This is the production path.
//! * [`run_event`] — single-threaded event-driven replay on a virtual
//!   clock with per-k costs and link latency. Publications take effect
//!   at the publisher's *finish* time (+ latency for peers), which
//!   reproduces the paper's "a k already executing is never killed"
//!   semantics exactly and makes visit counts a deterministic function
//!   of the schedule — what Fig 8/Fig 9 report. With [`UnitCost`] and
//!   zero latency this *is* the lockstep executor: unit costs quantize
//!   the timeline into rounds and round-r publications land at r+1.
//!
//! Every public search entry point (`binary_bleed_serial`,
//! `binary_bleed_parallel`, `binary_bleed_lockstep`,
//! `simulate_distributed`, `simulate_parallel_cluster`) is a thin
//! configuration of these two drivers; none of them carries its own
//! admit/evaluate/publish loop anymore.

use std::collections::BinaryHeap;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::super::bleed::SearchResult;
use super::super::evaluation::{KEvaluator, ScorerEvaluator};
use super::super::policy::SearchPolicy;
use super::super::rank::Broadcast;
use super::super::scorer::KScorer;
use super::super::state::{Admission, Candidate, ClaimEvent, SharedState};
use super::super::visit_log::{Decision, Visit, VisitLog};
use super::clock::{duration_from_minutes, Clock, VirtualClock, WallClock};
use super::transport::{SimNet, Transport};
use super::work::{bleed_order, WorkPlan, WorkerSlot};

/// Build the visit record for one evaluation.
fn eval_visit(
    seq: &AtomicU64,
    k: u32,
    score: f64,
    selected: bool,
    rank: usize,
    thread: usize,
    at: Duration,
) -> Visit {
    Visit {
        // ORDER: Relaxed — sequence numbers only need to be unique, which
        // the RMW guarantees at any ordering; visits are merged into the
        // log later under exclusive access, so no publication edge here.
        seq: seq.fetch_add(1, Ordering::Relaxed),
        k,
        score,
        decision: if selected {
            Decision::Selected
        } else {
            Decision::Rejected
        },
        rank,
        thread,
        at,
    }
}

/// Build the visit record for one pruned skip.
fn prune_visit(seq: &AtomicU64, k: u32, rank: usize, thread: usize, at: Duration) -> Visit {
    Visit {
        // ORDER: Relaxed — same contract as `eval_visit`: uniqueness from
        // the RMW alone; the log merge happens under exclusive access.
        seq: seq.fetch_add(1, Ordering::Relaxed),
        k,
        score: f64::NAN,
        decision: Decision::PrunedSkip,
        rank,
        thread,
        at,
    }
}

/// Build the visit record for one quarantined (permanently failed) k.
fn failed_visit(seq: &AtomicU64, k: u32, rank: usize, thread: usize, at: Duration) -> Visit {
    Visit {
        // ORDER: Relaxed — same contract as `eval_visit`.
        seq: seq.fetch_add(1, Ordering::Relaxed),
        k,
        score: f64::NAN,
        decision: Decision::Failed,
        rank,
        thread,
        at,
    }
}

/// ReceiveKCheck: merge every pending remote bound movement and claim
/// event into the rank-local state.
fn drain_and_merge(rank: usize, state: &SharedState, transport: &dyn Transport, now: Duration) {
    for msg in transport.drain(rank, now) {
        state.merge_remote(msg.floor, msg.ceil, msg.best);
        if let Some(ev) = msg.claim {
            state.merge_claim_event(ev);
        }
    }
}

/// The admitted half of the protocol step: evaluate, publish, settle
/// the lease, broadcast, build the visit. Shared by [`protocol_step`]
/// and the recovery sweep so stolen work follows the identical path.
///
/// The lease-settle transition gates the visit record: lease theft can
/// produce duplicate evaluations of one k (by design — duplicates waste
/// work, never correctness), but exactly one of them logs the k. An
/// `Err` outcome quarantines the k; the quarantine transition gates the
/// single `Failed` visit the same way.
#[allow(clippy::too_many_arguments)]
fn evaluate_admitted(
    rank: usize,
    thread: usize,
    k: u32,
    state: &SharedState,
    evaluator: &dyn KEvaluator,
    policy: &SearchPolicy,
    transport: &dyn Transport,
    clock: &dyn Clock,
    seq: &AtomicU64,
) -> Option<Visit> {
    if state.leases_enabled() {
        // Advertise the lease so peer sweeps leave in-progress work
        // alone. Advisory: a lost message costs duplicate work only.
        transport.broadcast(
            rank,
            clock.now(),
            Broadcast::claim_event(rank, ClaimEvent::Leased(k)),
        );
    }
    match evaluator.try_evaluate(k) {
        Ok(rec) => {
            // The full record lives on in whatever evaluator layer
            // produced it (an EvalCache retains it for the session);
            // the protocol itself only thresholds the primary score.
            let score = rec.score;
            let publication = state.publish(k, score, policy);
            let first = state.lease_complete(k);
            let claim = (first && state.leases_enabled()).then_some(ClaimEvent::Done(k));
            if !publication.is_empty() || claim.is_some() {
                // Alg 4 line 23: report the moved bound to every rank.
                transport.broadcast(
                    rank,
                    clock.now(),
                    Broadcast {
                        from: rank,
                        floor: publication.new_floor,
                        ceil: publication.new_ceil,
                        best: publication.new_best,
                        claim,
                    },
                );
            }
            first.then(|| eval_visit(seq, k, score, policy.selects(score), rank, thread, clock.now()))
        }
        Err(_err) => {
            // The evaluator (or its containment wrapper) gave up on k:
            // quarantine it and route the search around it.
            let first = state.mark_failed(k);
            if first && state.leases_enabled() {
                transport.broadcast(
                    rank,
                    clock.now(),
                    Broadcast::claim_event(rank, ClaimEvent::Failed(k)),
                );
            }
            first.then(|| failed_visit(seq, k, rank, thread, clock.now()))
        }
    }
}

/// Alg 4 for one k on one worker: ReceiveKCheck, admission, evaluation,
/// publication, BroadcastK. Returns the visit to record, or `None` when
/// another worker already claimed the k.
///
/// This is the *immediate-publication* form the threaded driver runs.
/// The event driver shares the same state protocol (admit /
/// merge_remote) and visit builders but must defer publication to the
/// evaluation's finish time — see the marked divergence in
/// [`run_event`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn protocol_step(
    rank: usize,
    thread: usize,
    k: u32,
    state: &SharedState,
    evaluator: &dyn KEvaluator,
    policy: &SearchPolicy,
    transport: &dyn Transport,
    clock: &dyn Clock,
    seq: &AtomicU64,
) -> Option<Visit> {
    // ReceiveKCheck: merge every pending remote bound movement.
    let now = clock.now();
    drain_and_merge(rank, state, transport, now);
    match state.admit(k, policy) {
        Admission::Admit => evaluate_admitted(
            rank, thread, k, state, evaluator, policy, transport, clock, seq,
        ),
        Admission::PrunedBySelect | Admission::PrunedByStop => {
            Some(prune_visit(seq, k, rank, thread, now))
        }
        // Failed: the quarantining worker already logged the Failed
        // visit; this worker just routes around the k.
        Admission::AlreadyClaimed | Admission::Failed => None,
    }
}

/// Fault-tolerant epilogue for lease-mode workers: after finishing its
/// own list, a worker sweeps the whole domain re-admitting ks whose
/// leases expired — a dead (or stalled) worker's claims are thereby
/// completed by the survivors (ROADMAP item 5: killed-rank ≡
/// uninterrupted). Each pass ticks the lease clock, so expiry needs no
/// wall-clock and no live holder: TTL sweep passes alone age a dead
/// worker's lease out.
///
/// The sweep records *only* the visits its own steals settle (the
/// lease-settle gate in [`evaluate_admitted`]); pruned/settled ks are
/// skipped silently — the owner's visit or the end-of-run
/// [`fill_pruned`] accounts for them.
#[allow(clippy::too_many_arguments)]
fn recovery_sweep(
    rank: usize,
    thread: usize,
    order: &[u32],
    state: &SharedState,
    evaluator: &dyn KEvaluator,
    policy: &SearchPolicy,
    transport: &dyn Transport,
    clock: &dyn Clock,
    seq: &AtomicU64,
    local: &mut VisitLog,
) {
    loop {
        state.lease_tick();
        let mut outstanding = false;
        let mut progress = false;
        for &k in order {
            drain_and_merge(rank, state, transport, clock.now());
            match state.admit(k, policy) {
                Admission::Admit => {
                    progress = true;
                    if let Some(v) = evaluate_admitted(
                        rank, thread, k, state, evaluator, policy, transport, clock, seq,
                    ) {
                        local.push(v);
                    }
                }
                Admission::AlreadyClaimed => {
                    // Unsettled lease: its holder may be alive (keep
                    // waiting) or dead (it will expire under our ticks).
                    if state.lease_outstanding(k) {
                        outstanding = true;
                    }
                }
                Admission::PrunedBySelect | Admission::PrunedByStop | Admission::Failed => {}
            }
        }
        if !outstanding {
            return;
        }
        if !progress {
            // Nothing stolen this pass: yield so live holders run.
            std::thread::yield_now();
        }
    }
}

/// Real-thread driver over a plain [`KScorer`] — the adapter-wrapped
/// form of [`run_threaded_ev`], kept so closures and scorers drive the
/// engine directly.
pub fn run_threaded(
    ks: &[u32],
    plan: &WorkPlan,
    states: &[SharedState],
    transport: &dyn Transport,
    scorer: &dyn KScorer,
    policy: SearchPolicy,
) -> SearchResult {
    run_threaded_ev(
        ks,
        plan,
        states,
        transport,
        &ScorerEvaluator::new(scorer),
        policy,
    )
}

/// Real-thread driver: one worker per plan slot, rank-shared states,
/// wall-clock timestamps. Single-worker plans run inline on the calling
/// thread (the serial regime spawns nothing). Takes the record-producing
/// [`KEvaluator`] — layer an [`EvalCache`](super::super::cache::EvalCache)
/// in front to deduplicate and retain the records.
pub fn run_threaded_ev(
    ks: &[u32],
    plan: &WorkPlan,
    states: &[SharedState],
    transport: &dyn Transport,
    evaluator: &dyn KEvaluator,
    policy: SearchPolicy,
) -> SearchResult {
    assert!(
        states.len() >= plan.ranks,
        "need one SharedState per rank ({} < {})",
        states.len(),
        plan.ranks
    );
    let clock = WallClock::start();
    let seq = AtomicU64::new(0);
    let log = Mutex::new(VisitLog::new());
    // Fault-tolerant mode is keyed off the states: leased claims mean
    // worker deaths are contained and survivors sweep for expired
    // leases. Without leases the driver behaves exactly as before —
    // a worker panic unwinds out of this function.
    let fault_tolerant = states.iter().any(SharedState::leases_enabled);
    let sweep_order = if fault_tolerant {
        bleed_order(ks)
    } else {
        Vec::new()
    };

    let run_worker = |slot: &WorkerSlot| {
        let state = &states[slot.rank];
        // Perf: visits buffer locally and merge under one lock at exit.
        let mut local = VisitLog::new();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for &k in &slot.list {
                if let Some(v) = protocol_step(
                    slot.rank,
                    slot.thread,
                    k,
                    state,
                    evaluator,
                    &policy,
                    transport,
                    &clock,
                    &seq,
                ) {
                    local.push(v);
                }
            }
            if fault_tolerant {
                recovery_sweep(
                    slot.rank,
                    slot.thread,
                    &sweep_order,
                    state,
                    evaluator,
                    &policy,
                    transport,
                    &clock,
                    &seq,
                    &mut local,
                );
            }
        }));
        // Merge even a dead worker's completed visits: their
        // publications are already in the shared state, so the log must
        // agree with it.
        if !local.visits.is_empty() {
            log.lock().unwrap().merge(local);
        }
        if let Err(payload) = outcome {
            if !fault_tolerant {
                // Pre-fault-tolerance semantics: a worker panic takes
                // the run down (the crash-then-`--resume` story).
                std::panic::resume_unwind(payload);
            }
            // Contained worker death: drop the payload; the lease layer
            // re-admits whatever ks this worker still held once their
            // leases expire under the survivors' sweeps.
        }
    };

    if plan.workers.len() <= 1 {
        if let Some(slot) = plan.workers.first() {
            run_worker(slot);
        }
    } else {
        let worker_ref = &run_worker;
        // bleedlint: allow(L3) -- engine *workers* are the outer layer of
        // the two-level budget (§3.2): one scoped thread per protocol
        // worker, joined before this function returns. The pool owns
        // intra-evaluation parallelism underneath; routing the protocol
        // layer through it would deadlock workers against their own
        // kernels' chunk claims.
        std::thread::scope(|scope| {
            for slot in &plan.workers {
                scope.spawn(move || worker_ref(slot));
            }
        });
    }

    let mut log = log.into_inner().unwrap();
    fill_pruned(&mut log, ks, &seq, clock.now());
    let best = fold_best(states);
    let failed_ks = log.failed();
    SearchResult {
        k_optimal: best.map(|c| c.k),
        score: best.map(|c| c.score),
        log,
        total_k: ks.len(),
        elapsed: clock.now(),
        partial: !failed_ks.is_empty(),
        failed_ks,
    }
}

/// Per-k evaluation cost for the event-driven driver.
pub trait EvalCost: Sync {
    /// Simulated minutes to evaluate the model at k.
    fn minutes(&self, k: u32) -> f64;
}

/// Every k costs one unit — quantizes the event timeline into lockstep
/// rounds.
pub struct UnitCost;

impl EvalCost for UnitCost {
    fn minutes(&self, _k: u32) -> f64 {
        1.0
    }
}

/// One completed evaluation on the simulated timeline.
#[derive(Debug, Clone)]
pub struct EvalSpan {
    pub k: u32,
    pub resource: usize,
    /// Simulated minutes.
    pub start: f64,
    pub end: f64,
    pub score: f64,
    pub selected: bool,
}

/// Result of an event-driven run.
#[derive(Debug, Clone)]
pub struct EventOutcome {
    /// Per-k decision log (visit `at` stamps carry simulated time).
    pub log: VisitLog,
    /// Folded candidate optimal across all resources.
    pub best: Option<Candidate>,
    /// Simulated makespan in minutes (serial regimes: the cost sum).
    pub makespan_minutes: f64,
    /// Evaluation trace, in launch order.
    pub spans: Vec<EvalSpan>,
}

/// Min-heap entry: (time, resource); ties broken by resource id so the
/// replay is deterministic.
#[derive(PartialEq)]
struct Ready(f64, usize);

impl Eq for Ready {}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed for min-heap behaviour of std's max-heap.
        other
            .0
            .partial_cmp(&self.0)
            .unwrap()
            .then(other.1.cmp(&self.1))
    }
}

/// Event-driven driver over a plain [`KScorer`] — the adapter-wrapped
/// form of [`run_event_ev`].
pub fn run_event(
    ks: &[u32],
    plan: &WorkPlan,
    scorer: &dyn KScorer,
    policy: SearchPolicy,
    cost: &dyn EvalCost,
    link_latency_minutes: f64,
) -> EventOutcome {
    run_event_ev(
        ks,
        plan,
        &ScorerEvaluator::new(scorer),
        policy,
        cost,
        link_latency_minutes,
    )
}

/// Event-driven driver: replays the plan on a virtual clock. Each
/// resource owns a rank-local [`SharedState`]; publications travel over
/// a [`SimNet`] and become visible at the publisher's finish time (plus
/// `link_latency_minutes` for peers). Evaluation is single-threaded
/// here, so a shared [`EvalCache`](super::super::cache::EvalCache)
/// serves replays deterministically: a cached k returns the identical
/// record, and the schedule stays a pure function of the plan.
pub fn run_event_ev(
    ks: &[u32],
    plan: &WorkPlan,
    evaluator: &dyn KEvaluator,
    policy: SearchPolicy,
    cost: &dyn EvalCost,
    link_latency_minutes: f64,
) -> EventOutcome {
    let resources = plan.workers.len().max(1);
    let states: Vec<SharedState> = (0..resources).map(|_| SharedState::new(ks)).collect();
    let net = SimNet::new(resources, duration_from_minutes(link_latency_minutes));
    let clock = VirtualClock::new();
    let seq = AtomicU64::new(0);
    let mut log = VisitLog::new();
    let mut spans: Vec<EvalSpan> = Vec::new();
    let mut cursors = vec![0usize; resources];
    let mut heap: BinaryHeap<Ready> = (0..plan.workers.len()).map(|r| Ready(0.0, r)).collect();
    let mut makespan = 0.0f64;

    while let Some(Ready(t, r)) = heap.pop() {
        clock.set_minutes(t);
        let now = clock.now();
        // ReceiveKCheck at the resource's current time.
        for msg in net.drain(r, now) {
            states[r].merge_remote(msg.floor, msg.ceil, msg.best);
            if let Some(ev) = msg.claim {
                states[r].merge_claim_event(ev);
            }
        }
        let slot = &plan.workers[r];
        // Pull the next admissible k; pruned skips cost zero time.
        while cursors[r] < slot.list.len() {
            let k = slot.list[cursors[r]];
            cursors[r] += 1;
            match states[r].admit(k, &policy) {
                Admission::Admit => {
                    let rec = match evaluator.try_evaluate(k) {
                        Ok(rec) => rec,
                        Err(_err) => {
                            // Quarantined k: zero simulated cost (the
                            // containment wrapper already charged the
                            // retries in real time; the schedule model
                            // treats a dead fit as instantaneous).
                            // Gossip the quarantine so peer resources
                            // route around it too.
                            if states[r].mark_failed(k) {
                                log.push(failed_visit(&seq, k, r, slot.thread, now));
                                net.broadcast(
                                    r,
                                    now,
                                    Broadcast::claim_event(r, ClaimEvent::Failed(k)),
                                );
                            }
                            continue;
                        }
                    };
                    let score = rec.score;
                    let end = t + cost.minutes(k);
                    let selected = policy.selects(score);
                    // INTENTIONAL DIVERGENCE from `protocol_step`: the
                    // event driver must NOT publish into the local state
                    // here — the result exists only at the finish time,
                    // so the whole publication rides the transport
                    // stamped `end` (the publisher itself sees it then,
                    // peers one latency later). In-flight k are
                    // therefore never killed (Fig 4) and lockstep
                    // rounds emerge under UnitCost. Everything else
                    // (admission, visit records, merge semantics) is
                    // shared with the threaded step.
                    let msg = Broadcast {
                        from: r,
                        floor: if selected && policy.prunes_on_select() {
                            Some(k)
                        } else {
                            None
                        },
                        ceil: if policy.stops(score) { Some(k) } else { None },
                        best: if selected {
                            Some(Candidate { k, score })
                        } else {
                            None
                        },
                        claim: None,
                    };
                    if msg.floor.is_some() || msg.ceil.is_some() || msg.best.is_some() {
                        net.broadcast(r, duration_from_minutes(end), msg);
                    }
                    log.push(eval_visit(&seq, k, score, selected, r, slot.thread, now));
                    spans.push(EvalSpan {
                        k,
                        resource: r,
                        start: t,
                        end,
                        score,
                        selected,
                    });
                    makespan = makespan.max(end);
                    heap.push(Ready(end, r));
                    break;
                }
                Admission::PrunedBySelect | Admission::PrunedByStop => {
                    log.push(prune_visit(&seq, k, r, slot.thread, now));
                }
                // Failed: the quarantining resource logged it already.
                Admission::AlreadyClaimed | Admission::Failed => {}
            }
        }
    }

    // Flush tail publications that no pop ever drained, so the folded
    // optimum reflects the whole run.
    for (r, state) in states.iter().enumerate() {
        for msg in net.drain(r, Duration::MAX) {
            state.merge_remote(msg.floor, msg.ceil, msg.best);
            if let Some(ev) = msg.claim {
                state.merge_claim_event(ev);
            }
        }
    }
    // The event driver builds every resource's state over the same
    // `ks` today, so the rejected channel folded by `fold_best` is
    // always empty here — sharing the helper keeps the two drivers'
    // shutdown semantics structurally identical regardless.
    let best = fold_best(&states);
    EventOutcome {
        log,
        best,
        makespan_minutes: makespan,
        spans,
    }
}

/// The shared shutdown fold of both drivers: the global candidate
/// optimal across every rank's local best *and* the remote bests each
/// rank parked as out-of-domain ([`SharedState::rejected_remote_bests`]),
/// under the paper's largest-k rule (ReceiveKCheck keeps the larger k).
/// Folding the parked bests means a heterogeneous-domain deployment
/// reports an optimum covering every rank's domain instead of silently
/// dropping k this rank never searched. Neither arm can carry a
/// non-finite score: local publication only follows a threshold
/// selection (false for NaN), and [`SharedState::merge_remote`] drops
/// corrupt (non-finite) remote bests at ingestion — in-domain and
/// out-of-domain alike — so a poisoned broadcast can never displace
/// the genuine optimum here.
fn fold_best(states: &[SharedState]) -> Option<Candidate> {
    states
        .iter()
        .flat_map(|s| s.best().into_iter().chain(s.rejected_remote_bests()))
        .max_by_key(|c| c.k)
}

/// Append PrunedSkip entries for k never touched by any worker, so the
/// log always partitions the search domain.
pub(crate) fn fill_pruned(log: &mut VisitLog, ks: &[u32], seq: &AtomicU64, at: Duration) {
    let seen: HashSet<u32> = log.visits.iter().map(|v| v.k).collect();
    for &k in ks {
        if !seen.contains(&k) {
            log.push(prune_visit(seq, k, usize::MAX, 0, at));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::transport::Loopback;
    use super::super::work::bleed_order;
    use super::*;
    use crate::coordinator::chunk::Pipeline;
    use crate::coordinator::policy::{Mode, Thresholds};
    use crate::coordinator::traversal::Traversal;

    fn pol(mode: Mode) -> SearchPolicy {
        SearchPolicy::maximize(
            mode,
            Thresholds {
                select: 0.75,
                stop: 0.2,
            },
        )
    }

    fn square(k_true: u32) -> impl Fn(u32) -> f64 + Sync {
        move |k| if k <= k_true { 0.95 } else { 0.05 }
    }

    #[test]
    fn shutdown_fold_includes_rejected_remote_bests() {
        // A heterogeneous-domain peer broadcast its best at k = 40,
        // which lies outside this rank's {2..30} domain: merge_remote
        // parks it out-of-band, and the shutdown fold must still report
        // it as the global optimum (largest selected k wins).
        let ks: Vec<u32> = (2..=30).collect();
        let plan = WorkPlan::serial(&ks, Mode::Vanilla);
        let state = SharedState::new(&ks);
        state.merge_remote(None, None, Some(Candidate { k: 40, score: 0.91 }));
        // A corrupt broadcast (non-finite score) must never displace a
        // genuine optimum, no matter how large its k.
        state.merge_remote(
            None,
            None,
            Some(Candidate {
                k: 9999,
                score: f64::NAN,
            }),
        );
        let r = run_threaded(
            &ks,
            &plan,
            std::slice::from_ref(&state),
            &Loopback,
            &square(15),
            pol(Mode::Vanilla),
        );
        assert_eq!(r.k_optimal, Some(40));
        assert_eq!(r.score, Some(0.91));
        // The local domain is still fully decided.
        let mut all = r.log.evaluated();
        all.extend(r.log.pruned());
        all.sort_unstable();
        assert_eq!(all, ks);
    }

    #[test]
    fn shutdown_fold_prefers_larger_local_best() {
        // The largest-k rule cuts both ways: a smaller out-of-domain
        // remote best must not displace a larger local one.
        let ks: Vec<u32> = (10..=30).collect();
        let plan = WorkPlan::serial(&ks, Mode::Vanilla);
        let state = SharedState::new(&ks);
        state.merge_remote(None, None, Some(Candidate { k: 5, score: 0.99 }));
        let r = run_threaded(
            &ks,
            &plan,
            std::slice::from_ref(&state),
            &Loopback,
            &square(20),
            pol(Mode::Vanilla),
        );
        assert_eq!(r.k_optimal, Some(20));
    }

    #[test]
    fn threaded_serial_finds_ktrue() {
        let ks: Vec<u32> = (2..=30).collect();
        let plan = WorkPlan::serial(&ks, Mode::Vanilla);
        assert_eq!(plan.workers[0].list, bleed_order(&ks));
        let state = SharedState::new(&ks);
        let r = run_threaded(
            &ks,
            &plan,
            std::slice::from_ref(&state),
            &Loopback,
            &square(15),
            pol(Mode::Vanilla),
        );
        assert_eq!(r.k_optimal, Some(15));
    }

    #[test]
    fn event_unit_cost_forms_rounds() {
        // 2 resources, unit cost: the first two evaluations start at 0,
        // the next pair at 1 — lockstep rounds.
        let ks: Vec<u32> = (2..=9).collect();
        let plan = WorkPlan::flat(&ks, 2, Traversal::InOrder, Pipeline::SkipModThenSort);
        let out = run_event(
            &ks,
            &plan,
            &square(9),
            pol(Mode::Standard),
            &UnitCost,
            0.0,
        );
        assert_eq!(out.spans.len(), 8);
        let round0: Vec<&EvalSpan> = out.spans.iter().filter(|s| s.start == 0.0).collect();
        assert_eq!(round0.len(), 2);
        assert_eq!(out.makespan_minutes, 4.0);
        assert_eq!(out.best.unwrap().k, 9);
    }

    #[test]
    fn event_latency_delays_pruning() {
        // In-order lists on 2 resources; with huge link latency the
        // selection on one resource never reaches the other, so strictly
        // more k are evaluated than with instant links.
        let ks: Vec<u32> = (2..=40).collect();
        let plan = WorkPlan::flat(&ks, 2, Traversal::PreOrder, Pipeline::SkipModThenSort);
        let fast = run_event(&ks, &plan, &square(35), pol(Mode::Vanilla), &UnitCost, 0.0);
        let slow = run_event(
            &ks,
            &plan,
            &square(35),
            pol(Mode::Vanilla),
            &UnitCost,
            1e6,
        );
        assert_eq!(fast.best.map(|c| c.k), Some(35));
        assert_eq!(slow.best.map(|c| c.k), Some(35));
        assert!(
            slow.spans.len() >= fast.spans.len(),
            "latency cannot improve pruning: {} < {}",
            slow.spans.len(),
            fast.spans.len()
        );
    }

    #[test]
    fn event_log_partitions_domain() {
        let ks: Vec<u32> = (2..=30).collect();
        let plan = WorkPlan::flat(&ks, 3, Traversal::PreOrder, Pipeline::SkipModThenSort);
        let out = run_event(&ks, &plan, &square(11), pol(Mode::EarlyStop), &UnitCost, 0.0);
        let mut all = out.log.evaluated();
        all.extend(out.log.pruned());
        all.sort_unstable();
        assert_eq!(all, ks);
    }
}
