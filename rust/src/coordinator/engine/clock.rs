//! Time sources for the engine: wall-clock for real execution, virtual
//! for the deterministic / simulated regimes.
//!
//! The engine core stamps every visit with `clock.now()` and hands the
//! same timestamps to the transport, so swapping [`WallClock`] for
//! [`VirtualClock`] is all it takes to move a regime from "as fast as
//! the host runs" to "replayed on a simulated timeline" (Fig 8 vs Fig 9).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::Stopwatch;

/// A monotonically non-decreasing time source.
pub trait Clock: Sync {
    /// Elapsed time since the search began.
    fn now(&self) -> Duration;
}

/// Real elapsed time (the production multi-rank/multi-thread regime).
pub struct WallClock {
    sw: Stopwatch,
}

impl WallClock {
    pub fn start() -> Self {
        Self {
            sw: Stopwatch::new(),
        }
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.sw.elapsed()
    }
}

/// Driver-advanced virtual time in nanoseconds (event-driven regimes).
#[derive(Default)]
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance to an absolute simulated time given in minutes (the cost
    /// models' unit). Saturates instead of wrapping on absurd inputs.
    pub fn set_minutes(&self, minutes: f64) {
        let nanos = duration_from_minutes(minutes).as_nanos();
        // ORDER: Relaxed — the driver advances the clock between engine
        // steps, never concurrently with readers that need a fresher
        // value; `now()` only feeds timestamps, not synchronization.
        self.nanos
            .store(u64::try_from(nanos).unwrap_or(u64::MAX), Ordering::Relaxed);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        // ORDER: Relaxed — pure value read; see `set_minutes`.
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }
}

/// Simulated-minutes → `Duration`, clamped to non-negative finite values.
pub fn duration_from_minutes(minutes: f64) -> Duration {
    if minutes.is_finite() && minutes > 0.0 {
        Duration::from_secs_f64(minutes * 60.0)
    } else {
        Duration::ZERO
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances_on_set() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.set_minutes(2.0);
        assert_eq!(c.now(), Duration::from_secs(120));
    }

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::start();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn minute_conversion_clamps_garbage() {
        assert_eq!(duration_from_minutes(-3.0), Duration::ZERO);
        assert_eq!(duration_from_minutes(f64::NAN), Duration::ZERO);
        assert_eq!(duration_from_minutes(0.5), Duration::from_secs(30));
    }
}
