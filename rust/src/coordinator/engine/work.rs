//! Work sources for the engine: who evaluates which k, in what order.
//!
//! Every execution regime reduces to a [`WorkPlan`] — one ordered k list
//! per worker slot — built from the same chunk/traversal front-end
//! (Alg 2 / Table II / Fig 1):
//!
//! * [`WorkPlan::serial`] — one slot consuming the Alg 1 recursion order
//!   (midpoint first, **higher-k half before lower**), or the plain
//!   ascending list for the Standard baseline.
//! * [`WorkPlan::ranked`] — Alg 3: `Pipeline::split` deals k across
//!   ranks, then worker threads inside a rank take strided positions
//!   `t, t+T, t+2T, ...` of the rank's list (Alg 3 line 13).
//! * [`WorkPlan::flat`] — one slot per resource (lockstep rounds and the
//!   event-driven cluster simulators).

use super::super::chunk::Pipeline;
use super::super::policy::Mode;
use super::super::traversal::Traversal;

/// One worker's assignment: identity plus its ordered k list.
#[derive(Debug, Clone)]
pub struct WorkerSlot {
    /// Rank (node) this worker belongs to; indexes the per-rank state.
    pub rank: usize,
    /// Thread index within the rank (0 for single-threaded regimes).
    pub thread: usize,
    /// The k values this worker visits, in order.
    pub list: Vec<u32>,
}

/// The full work assignment of a search: a partition of the k domain
/// into per-worker ordered lists.
#[derive(Debug, Clone)]
pub struct WorkPlan {
    pub workers: Vec<WorkerSlot>,
    /// Number of ranks (distinct shared-state instances).
    pub ranks: usize,
}

impl WorkPlan {
    /// Single worker following Alg 1's serial order.
    pub fn serial(ks: &[u32], mode: Mode) -> WorkPlan {
        let list = match mode {
            Mode::Standard => ks.to_vec(),
            Mode::Vanilla | Mode::EarlyStop => bleed_order(ks),
        };
        WorkPlan {
            workers: vec![WorkerSlot {
                rank: 0,
                thread: 0,
                list,
            }],
            ranks: 1,
        }
    }

    /// Alg 3 shape: `ranks` nodes × `threads_per_rank` workers, the k
    /// domain dealt by `pipeline`/`traversal`, threads striding their
    /// rank's list.
    pub fn ranked(
        ks: &[u32],
        ranks: usize,
        threads_per_rank: usize,
        traversal: Traversal,
        pipeline: Pipeline,
    ) -> WorkPlan {
        let ranks = ranks.max(1);
        let threads = threads_per_rank.max(1);
        let chunks = pipeline.split(ks, ranks, traversal);
        let mut workers = Vec::with_capacity(ranks * threads);
        for (rank, chunk) in chunks.into_iter().enumerate() {
            for thread in 0..threads {
                let list: Vec<u32> = chunk
                    .iter()
                    .skip(thread)
                    .step_by(threads)
                    .copied()
                    .collect();
                workers.push(WorkerSlot { rank, thread, list });
            }
        }
        WorkPlan { workers, ranks }
    }

    /// One slot per resource (rank = resource id, thread 0) — the shape
    /// of the lockstep executor and the cluster simulators.
    pub fn flat(
        ks: &[u32],
        resources: usize,
        traversal: Traversal,
        pipeline: Pipeline,
    ) -> WorkPlan {
        let resources = resources.max(1);
        let chunks = pipeline.split(ks, resources, traversal);
        let workers = chunks
            .into_iter()
            .enumerate()
            .map(|(rank, list)| WorkerSlot {
                rank,
                thread: 0,
                list,
            })
            .collect();
        WorkPlan {
            workers,
            ranks: resources,
        }
    }
}

/// Alg 1's visit order: ceiling midpoint first, then the **higher-k
/// half**, then the lower half ("the search continues in the direction
/// of optimization" — upward exploration maximizes subsequent pruning).
/// Note this differs from [`Traversal::PreOrder`], which serializes the
/// lower half first.
pub fn bleed_order(ks: &[u32]) -> Vec<u32> {
    fn rec(ks: &[u32], lo: usize, hi: usize, out: &mut Vec<u32>) {
        if lo > hi {
            return;
        }
        let m = lo + (hi - lo + 1) / 2;
        out.push(ks[m]);
        if m < hi {
            rec(ks, m + 1, hi, out);
        }
        if m > lo {
            rec(ks, lo, m - 1, out);
        }
    }
    let mut out = Vec::with_capacity(ks.len());
    if !ks.is_empty() {
        rec(ks, 0, ks.len() - 1, &mut out);
    }
    out
}

/// Release-mode input validation for every public search entry point:
/// the bounds arithmetic (floor/ceil pruning, bitmap positions) requires
/// a strictly ascending k list, so unsorted or duplicated input is
/// sorted and deduplicated instead of silently corrupting the search
/// (the seed only `debug_assert!`ed, which vanishes under `--release`).
pub fn normalize_ks(ks: &[u32]) -> Vec<u32> {
    let mut v = ks.to_vec();
    if !v.windows(2).all(|w| w[0] < w[1]) {
        v.sort_unstable();
        v.dedup();
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bleed_order_visits_high_half_first() {
        // [1..11]: mid 6, then the upper subtree, then the lower.
        assert_eq!(
            bleed_order(&(1..=11).collect::<Vec<u32>>()),
            vec![6, 9, 11, 10, 8, 7, 3, 5, 4, 2, 1]
        );
    }

    #[test]
    fn bleed_order_is_permutation() {
        let ks: Vec<u32> = (2..=30).collect();
        let mut sorted = bleed_order(&ks);
        sorted.sort_unstable();
        assert_eq!(sorted, ks);
        assert!(bleed_order(&[]).is_empty());
        assert_eq!(bleed_order(&[7]), vec![7]);
    }

    #[test]
    fn normalize_passes_sorted_through_and_fixes_bad_input() {
        let ks: Vec<u32> = (2..=9).collect();
        assert_eq!(normalize_ks(&ks), ks);
        assert_eq!(normalize_ks(&[5, 2, 9, 2, 7]), vec![2, 5, 7, 9]);
        assert_eq!(normalize_ks(&[]), Vec::<u32>::new());
    }

    #[test]
    fn ranked_plan_partitions_and_strides() {
        let ks: Vec<u32> = (1..=11).collect();
        let plan = WorkPlan::ranked(
            &ks,
            2,
            2,
            Traversal::PreOrder,
            Pipeline::SkipModThenSort,
        );
        assert_eq!(plan.ranks, 2);
        assert_eq!(plan.workers.len(), 4);
        // T4 pre rank 0 chunk is [7,3,1,5,11,9]; thread 0 takes even
        // positions, thread 1 odd.
        assert_eq!(plan.workers[0].list, vec![7, 1, 11]);
        assert_eq!(plan.workers[1].list, vec![3, 5, 9]);
        // Union of all lists is the whole domain.
        let mut all: Vec<u32> = plan
            .workers
            .iter()
            .flat_map(|w| w.list.iter().copied())
            .collect();
        all.sort_unstable();
        assert_eq!(all, ks);
    }

    #[test]
    fn flat_plan_one_slot_per_resource() {
        let ks: Vec<u32> = (1..=9).collect();
        let plan = WorkPlan::flat(&ks, 3, Traversal::InOrder, Pipeline::SkipModThenSort);
        assert_eq!(plan.workers.len(), 3);
        assert!(plan.workers.iter().all(|w| w.thread == 0));
        assert_eq!(plan.workers[1].rank, 1);
    }

    #[test]
    fn degenerate_shapes_clamp_to_one() {
        let plan = WorkPlan::ranked(
            &[2, 3],
            0,
            0,
            Traversal::PreOrder,
            Pipeline::SkipModThenSort,
        );
        assert_eq!(plan.ranks, 1);
        assert_eq!(plan.workers.len(), 1);
    }
}
