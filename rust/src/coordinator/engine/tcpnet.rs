//! `TcpNet`: the real multi-process transport (DESIGN.md §3.7).
//!
//! Each rank process binds one listening socket and dials one outgoing
//! connection to every peer (its broadcast channel), so an N-rank
//! cluster is a full mesh of 2·C(N,2) directed TCP streams. The first
//! frame on every connection is a [`WireMsg::Hello`] identifying the
//! dialer; after that the dialer writes [`WireMsg::Cast`] frames (the
//! protocol's BroadcastK traffic) and periodic [`WireMsg::Heartbeat`]
//! beacons, and the acceptor side only reads.
//!
//! Everything on the wire is advisory — the Binary Bleed protocol
//! already tolerates lost, duplicated, and reordered broadcasts (the
//! `FaultNet` conformance suite pins this) — so the send path never
//! blocks on recovery: a failed write just drops the connection and the
//! heartbeat thread redials it later under the seeded
//! [`RetryPolicy`] backoff schedule.
//!
//! # Heartbeat × lease clock
//!
//! Claim leases (DESIGN.md §3.6) age on a *logical* clock: sweep ticks,
//! not wall time. A dead thread stops ticking and its leases expire; a
//! dead **process** additionally stops gossiping. `TcpNet` closes that
//! gap from the liveness side: it watches its own outgoing claim gossip
//! to track which ks this process currently holds (`Leased` adds,
//! `Done`/`Failed` settles), and every heartbeat interval re-broadcasts
//! `Leased(k)` for each held k. On the receiving side that renewal is a
//! plain `merge_claim_event` → `fetch_max(now)`, which keeps a live
//! process's leases fresh in every peer's table no matter how fast the
//! peers tick. When the process dies the renewals stop, the survivors'
//! recovery sweeps age the orphaned leases past the TTL, and the dead
//! process's ks are re-admitted — the process-level analogue of the
//! killed-thread property in `rust/tests/fault_injection.rs`.
//!
//! The heartbeat thread is paced purely by `thread::sleep`; neither it
//! nor any other `TcpNet` path reads a wall clock (bleedlint L6).

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use super::super::fault::RetryPolicy;
use super::super::rank::Broadcast;
use super::super::state::ClaimEvent;
use super::transport::Transport;
use super::wire::{self, WireMsg, MAX_FRAME_LEN};
use crate::util::error::{ensure, Context, Result};

/// Connection-lifecycle knobs.
#[derive(Debug, Clone)]
pub struct TcpNetConfig {
    /// Dial schedule for initial connects and reconnects: up to
    /// `max_attempts` tries per peer, backing off per
    /// [`RetryPolicy::backoff_before`] (jitter seeded per peer rank, so
    /// a cluster cold-starting in lockstep doesn't dial in lockstep).
    pub retry: RetryPolicy,
    /// Heartbeat period: every tick redials dead links, re-broadcasts
    /// held claim leases, and sends a liveness beacon. `ZERO` disables
    /// the thread entirely (useful for single-shot codec tests).
    pub heartbeat: Duration,
}

impl Default for TcpNetConfig {
    fn default() -> Self {
        TcpNetConfig {
            // ~7s of dial patience: enough for a sibling process spawned
            // in the same orchestration round to bind its listener.
            retry: RetryPolicy {
                max_attempts: 400,
                base_backoff: Duration::from_millis(5),
                max_backoff: Duration::from_millis(25),
                seed: 0xB1EED,
            },
            heartbeat: Duration::from_millis(25),
        }
    }
}

/// Counters for observability and tests (snapshot via [`TcpNet::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TcpStats {
    pub sent: u64,
    pub received: u64,
    pub send_errors: u64,
    pub corrupt_frames: u64,
    pub reconnects: u64,
    pub heartbeats_out: u64,
}

/// Reconnect pacing for one dead link, advanced by the heartbeat thread.
#[derive(Debug, Default)]
struct DialState {
    /// Failed dials since the link last worked.
    attempts: u32,
    /// Heartbeat ticks to skip before the next dial (the backoff
    /// schedule quantized to beats).
    skip_beats: u32,
}

/// One outgoing link to a peer.
struct PeerLink {
    addr: SocketAddr,
    conn: Mutex<Option<TcpStream>>,
    dial: Mutex<DialState>,
}

struct Shared {
    rank: usize,
    /// Indexed by peer rank; `None` at our own slot.
    links: Vec<Option<PeerLink>>,
    /// Broadcasts received from peers, drained by the engine.
    inbox: Mutex<Vec<Broadcast>>,
    /// ks this process currently holds a lease on (observed from our
    /// own outgoing claim gossip); renewed every heartbeat.
    held: Mutex<Vec<u32>>,
    /// Read-half clones of accepted connections, shut down on Drop to
    /// unblock the reader threads.
    accepted: Mutex<Vec<TcpStream>>,
    reader_handles: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Liveness beacons seen per peer rank (tests assert on this).
    beats_from: Mutex<Vec<u64>>,
    stop: AtomicBool,
    retry: RetryPolicy,
    sent: AtomicU64,
    received: AtomicU64,
    send_errors: AtomicU64,
    corrupt_frames: AtomicU64,
    reconnects: AtomicU64,
    heartbeats_out: AtomicU64,
}

impl Shared {
    fn stopped(&self) -> bool {
        // ORDER: Relaxed — the stop flag is a latch polled by loops that
        // also sleep/block; no data is published through it (everything
        // the threads touch is behind mutexes).
        self.stop.load(Ordering::Relaxed)
    }

    /// Write one pre-encoded frame to every live link; a failed write
    /// drops that link (the heartbeat redials it).
    fn fan_out(&self, bytes: &[u8]) {
        for link in self.links.iter().flatten() {
            let mut guard = link.conn.lock().unwrap();
            let ok = match guard.as_mut() {
                Some(stream) => stream.write_all(bytes).is_ok(),
                None => false,
            };
            if ok {
                // ORDER: Relaxed — monotonic counter, read only in
                // stats snapshots.
                self.sent.fetch_add(1, Ordering::Relaxed);
            } else if guard.take().is_some() {
                // Only a *failed write* is a send error; a link already
                // down just drops the advisory message.
                // ORDER: Relaxed — monotonic counter (see above).
                self.send_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Track our own claim gossip so the heartbeat can renew leases.
    fn note_claim(&self, ev: ClaimEvent) {
        let mut held = self.held.lock().unwrap();
        match ev {
            ClaimEvent::Leased(k) => {
                if !held.contains(&k) {
                    held.push(k);
                }
            }
            ClaimEvent::Done(k) | ClaimEvent::Failed(k) => held.retain(|&h| h != k),
        }
    }
}

/// A bound-but-not-yet-connected listener. Splitting bind from connect
/// lets a cluster bind every listener (possibly on ephemeral `:0`
/// ports) before any rank starts dialing.
pub struct TcpBound {
    listener: TcpListener,
    local: SocketAddr,
}

/// The TCP [`Transport`]: one instance per rank process (or one per
/// simulated rank inside a test — see [`TcpFabric`]).
pub struct TcpNet {
    shared: Arc<Shared>,
    local: SocketAddr,
    /// Acceptor + heartbeat threads, joined on Drop.
    service_handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl TcpNet {
    /// Bind the listening socket for one rank. `addr` may use port 0 to
    /// let the OS pick (read it back via [`TcpBound::local_addr`]).
    pub fn bind(addr: &str) -> Result<TcpBound> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding rank listener on {addr}"))?;
        let local = listener
            .local_addr()
            .context("reading bound listener address")?;
        Ok(TcpBound { listener, local })
    }

    /// Bind + connect in one step: join the cluster described by
    /// `addrs` as rank `rank` (binding on `addrs[rank]`).
    pub fn join(rank: usize, addrs: &[String], cfg: TcpNetConfig) -> Result<TcpNet> {
        ensure!(rank < addrs.len(), "rank {rank} outside {} addrs", addrs.len());
        Self::bind(&addrs[rank])?.connect(rank, addrs, cfg)
    }

    pub fn rank(&self) -> usize {
        self.shared.rank
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    pub fn stats(&self) -> TcpStats {
        // ORDER: Relaxed — advisory counters; each is independently
        // monotonic and the snapshot makes no cross-field claims.
        TcpStats {
            sent: self.shared.sent.load(Ordering::Relaxed),
            received: self.shared.received.load(Ordering::Relaxed),
            send_errors: self.shared.send_errors.load(Ordering::Relaxed),
            corrupt_frames: self.shared.corrupt_frames.load(Ordering::Relaxed),
            reconnects: self.shared.reconnects.load(Ordering::Relaxed),
            heartbeats_out: self.shared.heartbeats_out.load(Ordering::Relaxed),
        }
    }

    /// Liveness beacons received from `rank` so far.
    pub fn beats_from(&self, rank: usize) -> u64 {
        self.shared
            .beats_from
            .lock()
            .unwrap()
            .get(rank)
            .copied()
            .unwrap_or(0)
    }
}

impl TcpBound {
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Dial every peer in `addrs` (skipping our own slot), start the
    /// acceptor and heartbeat threads, and return the live transport.
    pub fn connect(self, rank: usize, addrs: &[String], cfg: TcpNetConfig) -> Result<TcpNet> {
        ensure!(addrs.len() >= 2, "a TCP cluster needs at least 2 ranks");
        ensure!(rank < addrs.len(), "rank {rank} outside {} addrs", addrs.len());
        let mut links = Vec::with_capacity(addrs.len());
        for (peer, addr) in addrs.iter().enumerate() {
            if peer == rank {
                links.push(None);
                continue;
            }
            let resolved = addr
                .to_socket_addrs()
                .with_context(|| format!("resolving peer address '{addr}'"))?
                .next()
                .with_context(|| format!("peer address '{addr}' resolved to nothing"))?;
            links.push(Some(PeerLink {
                addr: resolved,
                conn: Mutex::new(None),
                dial: Mutex::new(DialState::default()),
            }));
        }
        let ranks = addrs.len();
        let shared = Arc::new(Shared {
            rank,
            links,
            inbox: Mutex::new(Vec::new()),
            held: Mutex::new(Vec::new()),
            accepted: Mutex::new(Vec::new()),
            reader_handles: Mutex::new(Vec::new()),
            beats_from: Mutex::new(vec![0; ranks]),
            stop: AtomicBool::new(false),
            retry: cfg.retry,
            sent: AtomicU64::new(0),
            received: AtomicU64::new(0),
            send_errors: AtomicU64::new(0),
            corrupt_frames: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            heartbeats_out: AtomicU64::new(0),
        });

        // Acceptor: non-blocking accept + short sleeps, so shutdown is
        // a flag flip away (no wall-clock reads, no self-connect hack).
        self.listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let acceptor_shared = Arc::clone(&shared);
        let listener = self.listener;
        // bleedlint: allow(L3) -- transport service thread: the acceptor
        // outlives any one search and cannot run on the scoped eval pool.
        let acceptor = thread::spawn(move || acceptor_loop(&listener, &acceptor_shared));

        // Dial every peer now, with seeded backoff; peers bound before
        // us queue the connection in their listen backlog even if their
        // acceptor thread isn't up yet.
        for peer in 0..ranks {
            if peer != rank {
                dial_blocking(&shared, peer)?;
            }
        }

        let mut service_handles = vec![acceptor];
        if !cfg.heartbeat.is_zero() {
            let hb_shared = Arc::clone(&shared);
            let period = cfg.heartbeat;
            // bleedlint: allow(L3) -- transport service thread: the
            // heartbeat paces lease renewal for the process lifetime.
            service_handles.push(thread::spawn(move || heartbeat_loop(&hb_shared, period)));
        }

        Ok(TcpNet {
            shared,
            local: self.local,
            service_handles: Mutex::new(service_handles),
        })
    }
}

/// Prepare a just-connected outgoing stream: low-latency writes, a
/// bounded write stall (a peer that stops draining must not wedge the
/// engine's publish path), and the identifying Hello frame.
fn prime_stream(stream: &TcpStream, rank: usize) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut hello = Vec::with_capacity(16);
    wire::encode(&WireMsg::Hello { rank: rank as u32 }, &mut hello);
    let mut writer = stream;
    writer.write_all(&hello)
}

/// Initial connect: retry under the policy's backoff schedule, blocking
/// this (construction-time) thread between attempts.
fn dial_blocking(shared: &Shared, peer: usize) -> Result<()> {
    let link = shared.links[peer].as_ref().expect("peer link exists");
    let mut attempt = 1u32;
    loop {
        match TcpStream::connect(link.addr) {
            Ok(stream) => {
                prime_stream(&stream, shared.rank)
                    .with_context(|| format!("priming connection to rank {peer}"))?;
                *link.conn.lock().unwrap() = Some(stream);
                return Ok(());
            }
            Err(e) => {
                if attempt >= shared.retry.max_attempts.max(1) {
                    return Err(crate::anyhow!(
                        "dialing rank {peer} at {}: {e} (gave up after {attempt} attempts)",
                        link.addr
                    ));
                }
                attempt += 1;
                thread::sleep(shared.retry.backoff_before(peer as u32, attempt));
            }
        }
    }
}

fn acceptor_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    while !shared.stopped() {
        match listener.accept() {
            Ok((stream, _)) => {
                // Readers block on read_exact; keep their socket
                // blocking and stash a clone so Drop can unblock them.
                let _ = stream.set_nonblocking(false);
                if let Ok(clone) = stream.try_clone() {
                    shared.accepted.lock().unwrap().push(clone);
                }
                let reader_shared = Arc::clone(shared);
                // bleedlint: allow(L3) -- transport service thread: one
                // blocking frame-reader per accepted peer connection.
                let handle = thread::spawn(move || reader_loop(stream, &reader_shared));
                shared.reader_handles.lock().unwrap().push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
}

/// Read frames off one accepted connection until EOF/shutdown/corruption.
fn reader_loop(mut stream: TcpStream, shared: &Arc<Shared>) {
    let mut header = [0u8; 4];
    let mut payload = [0u8; MAX_FRAME_LEN];
    let mut greeted = false;
    while !shared.stopped() {
        if stream.read_exact(&mut header).is_err() {
            break; // EOF or shutdown: the peer is gone.
        }
        let len = match wire::frame_len(header) {
            Ok(len) => len,
            Err(_) => {
                // ORDER: Relaxed — monotonic counter, stats-only.
                shared.corrupt_frames.fetch_add(1, Ordering::Relaxed);
                break; // Framing lost: drop the connection.
            }
        };
        if stream.read_exact(&mut payload[..len]).is_err() {
            break;
        }
        let msg = match wire::decode_payload(&payload[..len]) {
            Ok(msg) => msg,
            Err(_) => {
                // ORDER: Relaxed — monotonic counter, stats-only.
                shared.corrupt_frames.fetch_add(1, Ordering::Relaxed);
                break;
            }
        };
        match msg {
            WireMsg::Hello { .. } if !greeted => greeted = true,
            WireMsg::Hello { .. } => {} // redundant re-hello: harmless
            _ if !greeted => {
                // Protocol violation: the first frame must identify the
                // dialer. ORDER: Relaxed — monotonic counter, stats-only.
                shared.corrupt_frames.fetch_add(1, Ordering::Relaxed);
                break;
            }
            WireMsg::Cast(b) => {
                shared.inbox.lock().unwrap().push(b);
                // ORDER: Relaxed — monotonic counter, stats-only; the
                // message itself is published by the inbox mutex.
                shared.received.fetch_add(1, Ordering::Relaxed);
            }
            WireMsg::Heartbeat { rank } => {
                let mut beats = shared.beats_from.lock().unwrap();
                if let Some(slot) = beats.get_mut(rank as usize) {
                    *slot += 1;
                }
            }
        }
    }
}

/// Heartbeat: redial dead links on the seeded backoff schedule, renew
/// held claim leases, and beacon liveness — paced purely by sleep.
fn heartbeat_loop(shared: &Arc<Shared>, period: Duration) {
    let mut beacon = Vec::with_capacity(16);
    wire::encode(
        &WireMsg::Heartbeat {
            rank: shared.rank as u32,
        },
        &mut beacon,
    );
    loop {
        thread::sleep(period);
        if shared.stopped() {
            return;
        }
        // 1. Reconnect dead links, one dial per due beat, spacing dials
        //    by the RetryPolicy backoff quantized to beats.
        for (peer, link) in shared.links.iter().enumerate() {
            let Some(link) = link else { continue };
            if link.conn.lock().unwrap().is_some() {
                *link.dial.lock().unwrap() = DialState::default();
                continue;
            }
            let mut dial = link.dial.lock().unwrap();
            if dial.skip_beats > 0 {
                dial.skip_beats -= 1;
                continue;
            }
            dial.attempts = dial.attempts.saturating_add(1);
            match TcpStream::connect(link.addr) {
                Ok(stream) if prime_stream(&stream, shared.rank).is_ok() => {
                    *link.conn.lock().unwrap() = Some(stream);
                    *dial = DialState::default();
                    // ORDER: Relaxed — monotonic counter, stats-only.
                    shared.reconnects.fetch_add(1, Ordering::Relaxed);
                }
                _ => {
                    let backoff = shared
                        .retry
                        .backoff_before(peer as u32, dial.attempts.saturating_add(1));
                    dial.skip_beats = beats_for(backoff, period);
                }
            }
        }
        // 2. Lease renewal: re-gossip Leased(k) for every k this
        //    process holds. Receivers fold it with fetch_max, so a live
        //    process's leases never age out under peers' sweep ticks.
        let held: Vec<u32> = shared.held.lock().unwrap().clone();
        for k in held {
            let mut frame = Vec::with_capacity(24);
            wire::encode(
                &WireMsg::Cast(Broadcast::claim_event(shared.rank, ClaimEvent::Leased(k))),
                &mut frame,
            );
            shared.fan_out(&frame);
        }
        // 3. Liveness beacon.
        shared.fan_out(&beacon);
        // ORDER: Relaxed — monotonic counter, stats-only.
        shared.heartbeats_out.fetch_add(1, Ordering::Relaxed);
    }
}

/// Quantize a backoff duration to whole heartbeat ticks (≥ 1 so a
/// failed dial never retries on the very next beat with zero spacing —
/// unless the policy really asked for zero backoff).
fn beats_for(backoff: Duration, period: Duration) -> u32 {
    if backoff.is_zero() || period.is_zero() {
        return 0;
    }
    let beats = backoff.as_nanos().div_ceil(period.as_nanos().max(1));
    beats.min(u128::from(u32::MAX)) as u32
}

impl Transport for TcpNet {
    fn broadcast(&self, from: usize, _now: Duration, msg: Broadcast) {
        debug_assert_eq!(from, self.shared.rank, "TcpNet sends only as its own rank");
        if let Some(ev) = msg.claim {
            self.shared.note_claim(ev);
        }
        let mut frame = Vec::with_capacity(40);
        wire::encode(&WireMsg::Cast(msg), &mut frame);
        self.shared.fan_out(&frame);
    }

    fn drain(&self, _rank: usize, _now: Duration) -> Vec<Broadcast> {
        std::mem::take(&mut *self.shared.inbox.lock().unwrap())
    }
}

impl Drop for TcpNet {
    fn drop(&mut self) {
        // ORDER: Relaxed — latch; the threads observe it after their
        // current blocking op is broken by the socket shutdowns below.
        self.shared.stop.store(true, Ordering::Relaxed);
        for link in self.shared.links.iter().flatten() {
            if let Some(stream) = link.conn.lock().unwrap().take() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        for stream in self.shared.accepted.lock().unwrap().drain(..) {
            let _ = stream.shutdown(Shutdown::Both);
        }
        // Acceptor first (it spawns readers), then a second shutdown
        // pass for any connection it accepted while we were draining
        // above (a late redial would otherwise leave its reader blocked
        // until the dialing peer exits), then the readers themselves.
        for handle in self.service_handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
        for stream in self.shared.accepted.lock().unwrap().drain(..) {
            let _ = stream.shutdown(Shutdown::Both);
        }
        for handle in self.shared.reader_handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

/// An in-process bundle of N [`TcpNet`] rank endpoints presented as one
/// multi-rank [`Transport`]: `broadcast(from, …)` routes to rank
/// `from`'s endpoint, `drain(rank, …)` to rank `rank`'s. This is what
/// lets the in-process engine drivers (and `FaultNet`, unchanged) run
/// over real loopback TCP sockets in tests.
pub struct TcpFabric {
    nets: Vec<TcpNet>,
}

impl TcpFabric {
    /// Stand up an N-rank full mesh on ephemeral loopback ports: bind
    /// every listener first, then connect every rank.
    pub fn local(ranks: usize, cfg: TcpNetConfig) -> Result<TcpFabric> {
        ensure!(ranks >= 2, "a TCP fabric needs at least 2 ranks");
        let bounds: Vec<TcpBound> = (0..ranks)
            .map(|_| TcpNet::bind("127.0.0.1:0"))
            .collect::<Result<_>>()?;
        let addrs: Vec<String> = bounds.iter().map(|b| b.local_addr().to_string()).collect();
        let nets = bounds
            .into_iter()
            .enumerate()
            .map(|(rank, bound)| bound.connect(rank, &addrs, cfg.clone()))
            .collect::<Result<_>>()?;
        Ok(TcpFabric { nets })
    }

    pub fn ranks(&self) -> usize {
        self.nets.len()
    }

    pub fn net(&self, rank: usize) -> &TcpNet {
        &self.nets[rank]
    }
}

impl Transport for TcpFabric {
    fn broadcast(&self, from: usize, now: Duration, msg: Broadcast) {
        self.nets[from].broadcast(from, now, msg);
    }

    fn drain(&self, rank: usize, now: Duration) -> Vec<Broadcast> {
        self.nets[rank].drain(rank, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::Candidate;

    fn fast_cfg(heartbeat_ms: u64) -> TcpNetConfig {
        TcpNetConfig {
            retry: RetryPolicy {
                max_attempts: 100,
                base_backoff: Duration::from_millis(1),
                max_backoff: Duration::from_millis(5),
                seed: 7,
            },
            heartbeat: Duration::from_millis(heartbeat_ms),
        }
    }

    /// Poll-drain until `want` messages arrive or ~2s elapse (delivery
    /// is async; the settle loop is bounded, not timed by a clock read).
    fn drain_until(net: &TcpNet, want: usize) -> Vec<Broadcast> {
        let mut got = Vec::new();
        for _ in 0..2000 {
            got.extend(net.drain(net.rank(), Duration::ZERO));
            if got.len() >= want {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        got
    }

    #[test]
    fn fabric_delivers_to_peers_only() {
        let fabric = TcpFabric::local(3, fast_cfg(0)).unwrap();
        let msg = Broadcast::bounds(0, Some(9), None, Some(Candidate { k: 9, score: 0.75 }));
        fabric.broadcast(0, Duration::ZERO, msg);
        for rank in 1..3 {
            let got = drain_until(fabric.net(rank), 1);
            assert_eq!(got, vec![msg], "rank {rank} got the exact broadcast");
        }
        thread::sleep(Duration::from_millis(20));
        assert!(
            fabric.net(0).drain(0, Duration::ZERO).is_empty(),
            "no self-delivery"
        );
    }

    #[test]
    fn heartbeat_beacons_flow_between_ranks() {
        let fabric = TcpFabric::local(2, fast_cfg(5)).unwrap();
        for _ in 0..2000 {
            if fabric.net(0).beats_from(1) >= 3 && fabric.net(1).beats_from(0) >= 3 {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        assert!(fabric.net(0).beats_from(1) >= 3, "beacons from rank 1");
        assert!(fabric.net(1).beats_from(0) >= 3, "beacons from rank 0");
    }

    #[test]
    fn held_leases_are_renewed_until_settled() {
        let fabric = TcpFabric::local(2, fast_cfg(5)).unwrap();
        // Rank 0 leases k=12: the heartbeat should re-gossip it, so
        // rank 1 keeps receiving Leased(12) without further sends.
        fabric.broadcast(0, Duration::ZERO, Broadcast::claim_event(0, ClaimEvent::Leased(12)));
        let got = drain_until(fabric.net(1), 3);
        assert!(
            got.len() >= 3,
            "lease renewals keep arriving (got {})",
            got.len()
        );
        assert!(got
            .iter()
            .all(|b| b.claim == Some(ClaimEvent::Leased(12)) && b.from == 0));

        // Done(12) settles it: renewals stop (drain what's in flight,
        // then observe silence across several heartbeat periods).
        fabric.broadcast(0, Duration::ZERO, Broadcast::claim_event(0, ClaimEvent::Done(12)));
        thread::sleep(Duration::from_millis(40));
        fabric.net(1).drain(1, Duration::ZERO);
        thread::sleep(Duration::from_millis(40));
        let after = fabric.net(1).drain(1, Duration::ZERO);
        assert!(
            after.iter().all(|b| b.claim != Some(ClaimEvent::Leased(12))),
            "no renewals after Done: {after:?}"
        );
    }

    #[test]
    fn corrupt_frame_drops_connection_not_process() {
        let bound = TcpNet::bind("127.0.0.1:0").unwrap();
        let addr = bound.local_addr();
        // Rank 1 is a bare listener (kept alive so rank 0's dial lands
        // in its backlog); we then talk to rank 0 from a raw socket.
        let far = TcpNet::bind("127.0.0.1:0").unwrap();
        let addrs = vec![addr.to_string(), far.local_addr().to_string()];
        let net = bound.connect(0, &addrs, fast_cfg(0)).unwrap();
        let mut raw = TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        wire::encode(&WireMsg::Hello { rank: 1 }, &mut hello);
        raw.write_all(&hello).unwrap();
        // Oversized length prefix: the reader must reject and hang up.
        raw.write_all(&(MAX_FRAME_LEN as u32 + 99).to_be_bytes()).unwrap();
        raw.write_all(&[0u8; 8]).unwrap();
        for _ in 0..2000 {
            if net.stats().corrupt_frames > 0 {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(net.stats().corrupt_frames, 1, "typed rejection, counted");
        assert!(net.drain(0, Duration::ZERO).is_empty(), "nothing invented");
    }
}
