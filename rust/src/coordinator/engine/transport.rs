//! Pruning-propagation transports: how BroadcastK / ReceiveKCheck move
//! between ranks in each regime.
//!
//! * [`Loopback`] — single-rank regimes: every worker shares one
//!   [`SharedState`](super::super::state::SharedState), so there is
//!   nothing to send.
//! * [`MpscNet`] — the production multi-rank regime: in-process mpsc
//!   channel mailboxes (the seed's [`RankComm`] network) delivering
//!   broadcasts as fast as the host schedules threads.
//! * [`SimNet`] — simulated links with injectable latency for the Fig 9
//!   distributed regime: a broadcast becomes visible to the publisher at
//!   its own timestamp and to every peer `latency` later, which is what
//!   lets the event-driven driver replay "a k already executing is never
//!   killed" (Fig 4) and bandwidth-delayed pruning.

use std::sync::Mutex;
use std::time::Duration;

use super::super::rank::{Broadcast, RankComm};

/// Rank-to-rank propagation of bound movements.
pub trait Transport: Sync {
    /// BroadcastK: publish `msg` from `from` at time `now`.
    fn broadcast(&self, from: usize, now: Duration, msg: Broadcast);

    /// ReceiveKCheck: drain every message visible to `rank` at `now`.
    fn drain(&self, rank: usize, now: Duration) -> Vec<Broadcast>;
}

/// No-op transport for single-state regimes.
pub struct Loopback;

impl Transport for Loopback {
    fn broadcast(&self, _from: usize, _now: Duration, _msg: Broadcast) {}

    fn drain(&self, _rank: usize, _now: Duration) -> Vec<Broadcast> {
        Vec::new()
    }
}

/// Channel-mailbox network (wraps the seed's [`RankComm`] fabric).
pub struct MpscNet {
    comms: Vec<RankComm>,
}

impl MpscNet {
    pub fn new(ranks: usize) -> Self {
        Self {
            comms: RankComm::network(ranks.max(1)),
        }
    }
}

impl Transport for MpscNet {
    fn broadcast(&self, from: usize, _now: Duration, msg: Broadcast) {
        self.comms[from].broadcast(msg);
    }

    fn drain(&self, rank: usize, _now: Duration) -> Vec<Broadcast> {
        self.comms[rank].drain()
    }
}

/// Latency-injecting simulated links: messages carry a visibility time.
pub struct SimNet {
    latency: Duration,
    /// Per-destination pending messages: (visible_at, payload).
    boxes: Mutex<Vec<Vec<(Duration, Broadcast)>>>,
}

impl SimNet {
    pub fn new(ranks: usize, latency: Duration) -> Self {
        Self {
            latency,
            boxes: Mutex::new(vec![Vec::new(); ranks.max(1)]),
        }
    }
}

impl Transport for SimNet {
    fn broadcast(&self, from: usize, now: Duration, msg: Broadcast) {
        let mut boxes = self.boxes.lock().unwrap();
        for (dest, mailbox) in boxes.iter_mut().enumerate() {
            // The publisher sees its own movement immediately; peers see
            // it one link-latency later.
            let visible_at = if dest == from { now } else { now + self.latency };
            mailbox.push((visible_at, msg));
        }
    }

    fn drain(&self, rank: usize, now: Duration) -> Vec<Broadcast> {
        let mut boxes = self.boxes.lock().unwrap();
        let mailbox = &mut boxes[rank];
        // Fast path: the event driver polls far more often than messages
        // mature — when nothing is due, leave the pending vector alone
        // instead of rebuilding it.
        if !mailbox.iter().any(|&(at, _)| at <= now) {
            return Vec::new();
        }
        let mut due = Vec::new();
        // retain visits in order and preserves the survivors' relative
        // order, so same-timestamp messages drain in broadcast order
        // (the event driver's replay depends on this).
        mailbox.retain(|&(at, msg)| {
            if at <= now {
                due.push(msg);
                false
            } else {
                true
            }
        });
        due
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::transport::{check_transport_contract, TransportProfile};

    fn msg(floor: u32) -> Broadcast {
        Broadcast::bounds(0, Some(floor), None, None)
    }

    // The shared contract (peers-only/self delivery, exactly-once,
    // drain-once, burst multiset equality, per-sender FIFO) lives in
    // `crate::testing::transport`; `TcpNet` runs the same harness from
    // rust/tests/wire_transport.rs.

    #[test]
    fn loopback_meets_transport_contract() {
        check_transport_contract(&Loopback, &TransportProfile::loopback(3));
    }

    #[test]
    fn mpsc_net_meets_transport_contract() {
        check_transport_contract(&MpscNet::new(3), &TransportProfile::mpsc(3));
    }

    #[test]
    fn sim_net_meets_transport_contract_at_zero_latency() {
        let t = SimNet::new(3, Duration::ZERO);
        check_transport_contract(&t, &TransportProfile::sim(3, Duration::ZERO));
    }

    #[test]
    fn sim_net_meets_transport_contract_with_latency() {
        let latency = Duration::from_secs(60);
        let t = SimNet::new(2, latency);
        check_transport_contract(&t, &TransportProfile::sim(2, latency));
    }

    #[test]
    fn sim_net_same_timestamp_messages_drain_in_broadcast_order() {
        // Regression for the drain rewrite: the event driver replays
        // same-timestamp deliveries in broadcast order, so drain must
        // preserve mailbox insertion order exactly.
        let t = SimNet::new(2, Duration::ZERO);
        let now = Duration::from_secs(5);
        for k in [9u32, 3, 7, 5] {
            t.broadcast(1, now, msg(k));
        }
        let got: Vec<u32> = t
            .drain(0, now)
            .into_iter()
            .map(|b| b.floor.unwrap())
            .collect();
        assert_eq!(got, vec![9, 3, 7, 5], "broadcast order preserved");
    }

    #[test]
    fn sim_net_partial_drain_keeps_pending_order() {
        // Mixed due/pending mailbox: the due prefix leaves, the pending
        // suffix stays in order and arrives intact later.
        let t = SimNet::new(2, Duration::from_secs(10));
        t.broadcast(0, Duration::from_secs(0), msg(1)); // peer-due at 10
        t.broadcast(0, Duration::from_secs(5), msg(2)); // peer-due at 15
        t.broadcast(0, Duration::from_secs(5), msg(3)); // peer-due at 15
        // Nothing due yet: repeated early drains return empty without
        // disturbing the mailbox.
        for _ in 0..3 {
            assert!(t.drain(1, Duration::from_secs(9)).is_empty());
        }
        let first: Vec<u32> = t
            .drain(1, Duration::from_secs(10))
            .into_iter()
            .map(|b| b.floor.unwrap())
            .collect();
        assert_eq!(first, vec![1]);
        let rest: Vec<u32> = t
            .drain(1, Duration::from_secs(15))
            .into_iter()
            .map(|b| b.floor.unwrap())
            .collect();
        assert_eq!(rest, vec![2, 3], "pending survived early drains in order");
        assert!(t.drain(1, Duration::from_secs(100)).is_empty());
    }
}
