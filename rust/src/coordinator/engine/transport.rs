//! Pruning-propagation transports: how BroadcastK / ReceiveKCheck move
//! between ranks in each regime.
//!
//! * [`Loopback`] — single-rank regimes: every worker shares one
//!   [`SharedState`](super::super::state::SharedState), so there is
//!   nothing to send.
//! * [`MpscNet`] — the production multi-rank regime: in-process mpsc
//!   channel mailboxes (the seed's [`RankComm`] network) delivering
//!   broadcasts as fast as the host schedules threads.
//! * [`SimNet`] — simulated links with injectable latency for the Fig 9
//!   distributed regime: a broadcast becomes visible to the publisher at
//!   its own timestamp and to every peer `latency` later, which is what
//!   lets the event-driven driver replay "a k already executing is never
//!   killed" (Fig 4) and bandwidth-delayed pruning.

use std::sync::Mutex;
use std::time::Duration;

use super::super::rank::{Broadcast, RankComm};

/// Rank-to-rank propagation of bound movements.
pub trait Transport: Sync {
    /// BroadcastK: publish `msg` from `from` at time `now`.
    fn broadcast(&self, from: usize, now: Duration, msg: Broadcast);

    /// ReceiveKCheck: drain every message visible to `rank` at `now`.
    fn drain(&self, rank: usize, now: Duration) -> Vec<Broadcast>;
}

/// No-op transport for single-state regimes.
pub struct Loopback;

impl Transport for Loopback {
    fn broadcast(&self, _from: usize, _now: Duration, _msg: Broadcast) {}

    fn drain(&self, _rank: usize, _now: Duration) -> Vec<Broadcast> {
        Vec::new()
    }
}

/// Channel-mailbox network (wraps the seed's [`RankComm`] fabric).
pub struct MpscNet {
    comms: Vec<RankComm>,
}

impl MpscNet {
    pub fn new(ranks: usize) -> Self {
        Self {
            comms: RankComm::network(ranks.max(1)),
        }
    }
}

impl Transport for MpscNet {
    fn broadcast(&self, from: usize, _now: Duration, msg: Broadcast) {
        self.comms[from].broadcast(msg);
    }

    fn drain(&self, rank: usize, _now: Duration) -> Vec<Broadcast> {
        self.comms[rank].drain()
    }
}

/// Latency-injecting simulated links: messages carry a visibility time.
pub struct SimNet {
    latency: Duration,
    /// Per-destination pending messages: (visible_at, payload).
    boxes: Mutex<Vec<Vec<(Duration, Broadcast)>>>,
}

impl SimNet {
    pub fn new(ranks: usize, latency: Duration) -> Self {
        Self {
            latency,
            boxes: Mutex::new(vec![Vec::new(); ranks.max(1)]),
        }
    }
}

impl Transport for SimNet {
    fn broadcast(&self, from: usize, now: Duration, msg: Broadcast) {
        let mut boxes = self.boxes.lock().unwrap();
        for (dest, mailbox) in boxes.iter_mut().enumerate() {
            // The publisher sees its own movement immediately; peers see
            // it one link-latency later.
            let visible_at = if dest == from { now } else { now + self.latency };
            mailbox.push((visible_at, msg));
        }
    }

    fn drain(&self, rank: usize, now: Duration) -> Vec<Broadcast> {
        let mut boxes = self.boxes.lock().unwrap();
        let mailbox = &mut boxes[rank];
        let mut due = Vec::new();
        let mut pending = Vec::new();
        for (at, msg) in mailbox.drain(..) {
            if at <= now {
                due.push(msg);
            } else {
                pending.push((at, msg));
            }
        }
        *mailbox = pending;
        due
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::Candidate;

    fn msg(floor: u32) -> Broadcast {
        Broadcast::bounds(
            0,
            Some(floor),
            None,
            Some(Candidate {
                k: floor,
                score: 0.9,
            }),
        )
    }

    #[test]
    fn loopback_swallows_everything() {
        let t = Loopback;
        t.broadcast(0, Duration::ZERO, msg(5));
        assert!(t.drain(0, Duration::from_secs(100)).is_empty());
    }

    #[test]
    fn mpsc_net_delivers_to_peers_only() {
        let t = MpscNet::new(3);
        t.broadcast(0, Duration::ZERO, msg(7));
        assert!(t.drain(0, Duration::ZERO).is_empty());
        assert_eq!(t.drain(1, Duration::ZERO).len(), 1);
        assert_eq!(t.drain(2, Duration::ZERO).len(), 1);
    }

    #[test]
    fn sim_net_delays_peers_by_latency() {
        let t = SimNet::new(2, Duration::from_secs(60));
        t.broadcast(0, Duration::from_secs(10), msg(4));
        // Publisher sees it at t=10.
        assert_eq!(t.drain(0, Duration::from_secs(10)).len(), 1);
        // Peer sees nothing before t=70...
        assert!(t.drain(1, Duration::from_secs(69)).is_empty());
        // ...and the message exactly at t=70.
        let got = t.drain(1, Duration::from_secs(70));
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].floor, Some(4));
        // Drained messages are gone.
        assert!(t.drain(1, Duration::from_secs(700)).is_empty());
    }

    #[test]
    fn sim_net_zero_latency_is_immediate() {
        let t = SimNet::new(2, Duration::ZERO);
        t.broadcast(1, Duration::from_secs(5), msg(9));
        assert_eq!(t.drain(0, Duration::from_secs(5)).len(), 1);
    }
}
