//! Zero-dependency wire codec for the TCP transport (DESIGN.md §3.7).
//!
//! Every frame is length-prefixed — `[len: u32 BE][payload]` — and the
//! payload is `[kind: u8][body]`, all integers big-endian:
//!
//! | kind | message     | body                                        |
//! |------|-------------|---------------------------------------------|
//! | 1    | `Hello`     | `rank: u32` (first frame on a connection)   |
//! | 2    | `Cast`      | a full [`Broadcast`] (layout below)         |
//! | 3    | `Heartbeat` | `rank: u32` (liveness beacon)               |
//!
//! A [`Broadcast`] body is option-tagged field by field:
//!
//! ```text
//! from: u32
//! floor: tag u8 (0|1) [u32]
//! ceil:  tag u8 (0|1) [u32]
//! best:  tag u8 (0|1) [k: u32, score: u64 = f64::to_bits]
//! claim: tag u8 (0=none 1=leased 2=done 3=failed) [k: u32]
//! ```
//!
//! Scores cross the wire as raw IEEE-754 bits, so every peer rebuilds
//! the exact f64 the publisher computed — the bitwise half of the
//! "determinism over the wire" contract (NUMERICS.md). Decoding never
//! panics: malformed input comes back as a typed [`WireError`] and the
//! connection that produced it is dropped by the caller.

use super::super::rank::Broadcast;
use super::super::state::{Candidate, ClaimEvent};

/// Hard ceiling on a frame payload. The largest legal payload (a fully
/// populated `Cast`) is 28 bytes; anything claiming more than this is a
/// corrupt or hostile length prefix, rejected before any allocation.
pub const MAX_FRAME_LEN: usize = 64;

/// One decoded frame payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireMsg {
    /// Connection preamble: the dialing rank identifies itself.
    Hello { rank: u32 },
    /// A protocol broadcast (bounds / best / claim gossip).
    Cast(Broadcast),
    /// Liveness beacon from `rank` (no protocol content).
    Heartbeat { rank: u32 },
}

/// Typed decode failure — never a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ends before the field (or frame) it promises.
    Truncated { have: usize, need: usize },
    /// The length prefix exceeds [`MAX_FRAME_LEN`].
    Oversized { len: usize },
    /// Structurally invalid content (bad kind/tag, trailing bytes, …).
    Corrupt { what: &'static str },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { have, need } => {
                write!(f, "truncated frame: have {have} bytes, need {need}")
            }
            WireError::Oversized { len } => {
                write!(f, "oversized frame: {len} bytes (max {MAX_FRAME_LEN})")
            }
            WireError::Corrupt { what } => write!(f, "corrupt frame: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

const KIND_HELLO: u8 = 1;
const KIND_CAST: u8 = 2;
const KIND_HEARTBEAT: u8 = 3;

/// Append one length-prefixed frame for `msg` to `out`.
pub fn encode(msg: &WireMsg, out: &mut Vec<u8>) {
    let start = out.len();
    out.extend_from_slice(&[0u8; 4]); // length backpatched below
    match msg {
        WireMsg::Hello { rank } => {
            out.push(KIND_HELLO);
            out.extend_from_slice(&rank.to_be_bytes());
        }
        WireMsg::Cast(b) => {
            out.push(KIND_CAST);
            out.extend_from_slice(&(b.from as u32).to_be_bytes());
            put_opt_u32(out, b.floor);
            put_opt_u32(out, b.ceil);
            match b.best {
                None => out.push(0),
                Some(c) => {
                    out.push(1);
                    out.extend_from_slice(&c.k.to_be_bytes());
                    out.extend_from_slice(&c.score.to_bits().to_be_bytes());
                }
            }
            match b.claim {
                None => out.push(0),
                Some(ClaimEvent::Leased(k)) => {
                    out.push(1);
                    out.extend_from_slice(&k.to_be_bytes());
                }
                Some(ClaimEvent::Done(k)) => {
                    out.push(2);
                    out.extend_from_slice(&k.to_be_bytes());
                }
                Some(ClaimEvent::Failed(k)) => {
                    out.push(3);
                    out.extend_from_slice(&k.to_be_bytes());
                }
            }
        }
        WireMsg::Heartbeat { rank } => {
            out.push(KIND_HEARTBEAT);
            out.extend_from_slice(&rank.to_be_bytes());
        }
    }
    let len = out.len() - start - 4;
    debug_assert!(len <= MAX_FRAME_LEN, "encoder exceeded MAX_FRAME_LEN");
    out[start..start + 4].copy_from_slice(&(len as u32).to_be_bytes());
}

fn put_opt_u32(out: &mut Vec<u8>, v: Option<u32>) {
    match v {
        None => out.push(0),
        Some(x) => {
            out.push(1);
            out.extend_from_slice(&x.to_be_bytes());
        }
    }
}

/// Validate a length prefix. `Ok(n)` is the payload size to read next.
pub fn frame_len(header: [u8; 4]) -> Result<usize, WireError> {
    let len = u32::from_be_bytes(header) as usize;
    if len == 0 {
        return Err(WireError::Corrupt {
            what: "empty payload",
        });
    }
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversized { len });
    }
    Ok(len)
}

/// Sequential big-endian field reader over a payload slice.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated {
                have: self.buf.len(),
                need: self.pos + n,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_be_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_be_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn opt_u32(&mut self) -> Result<Option<u32>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u32()?)),
            _ => Err(WireError::Corrupt {
                what: "bad option tag",
            }),
        }
    }
}

/// Decode one payload (the bytes after the length prefix). Strict: any
/// trailing bytes after the message are rejected.
pub fn decode_payload(payload: &[u8]) -> Result<WireMsg, WireError> {
    let mut r = Reader {
        buf: payload,
        pos: 0,
    };
    let msg = match r.u8()? {
        KIND_HELLO => WireMsg::Hello { rank: r.u32()? },
        KIND_CAST => {
            let from = r.u32()? as usize;
            let floor = r.opt_u32()?;
            let ceil = r.opt_u32()?;
            let best = match r.u8()? {
                0 => None,
                1 => Some(Candidate {
                    k: r.u32()?,
                    score: f64::from_bits(r.u64()?),
                }),
                _ => {
                    return Err(WireError::Corrupt {
                        what: "bad best tag",
                    })
                }
            };
            let claim = match r.u8()? {
                0 => None,
                1 => Some(ClaimEvent::Leased(r.u32()?)),
                2 => Some(ClaimEvent::Done(r.u32()?)),
                3 => Some(ClaimEvent::Failed(r.u32()?)),
                _ => {
                    return Err(WireError::Corrupt {
                        what: "bad claim tag",
                    })
                }
            };
            WireMsg::Cast(Broadcast {
                from,
                floor,
                ceil,
                best,
                claim,
            })
        }
        KIND_HEARTBEAT => WireMsg::Heartbeat { rank: r.u32()? },
        _ => {
            return Err(WireError::Corrupt {
                what: "unknown frame kind",
            })
        }
    };
    if r.pos != payload.len() {
        return Err(WireError::Corrupt {
            what: "trailing bytes",
        });
    }
    Ok(msg)
}

/// Decode one full frame (prefix + payload) from the front of `buf`;
/// returns the message and the number of bytes consumed. A buffer
/// shorter than the frame it promises is `Truncated`.
pub fn decode_frame(buf: &[u8]) -> Result<(WireMsg, usize), WireError> {
    if buf.len() < 4 {
        return Err(WireError::Truncated {
            have: buf.len(),
            need: 4,
        });
    }
    let len = frame_len([buf[0], buf[1], buf[2], buf[3]])?;
    if buf.len() < 4 + len {
        return Err(WireError::Truncated {
            have: buf.len(),
            need: 4 + len,
        });
    }
    let msg = decode_payload(&buf[4..4 + len])?;
    Ok((msg, 4 + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: WireMsg) {
        let mut buf = Vec::new();
        encode(&msg, &mut buf);
        let (back, used) = decode_frame(&buf).unwrap();
        assert_eq!(used, buf.len(), "frame self-describes its length");
        assert_eq!(back, msg);
    }

    #[test]
    fn hello_and_heartbeat_roundtrip() {
        roundtrip(WireMsg::Hello { rank: 0 });
        roundtrip(WireMsg::Hello { rank: u32::MAX });
        roundtrip(WireMsg::Heartbeat { rank: 7 });
    }

    #[test]
    fn cast_roundtrips_every_field_shape() {
        roundtrip(WireMsg::Cast(Broadcast::bounds(3, None, None, None)));
        roundtrip(WireMsg::Cast(Broadcast::bounds(
            0,
            Some(11),
            Some(40),
            Some(Candidate {
                k: 11,
                score: 0.8125,
            }),
        )));
        for ev in [
            ClaimEvent::Leased(5),
            ClaimEvent::Done(6),
            ClaimEvent::Failed(7),
        ] {
            roundtrip(WireMsg::Cast(Broadcast::claim_event(2, ev)));
        }
    }

    #[test]
    fn score_bits_survive_exactly() {
        // Subnormals, negative zero, and "ugly" decimals all cross the
        // wire bit-for-bit.
        for score in [f64::MIN_POSITIVE / 2.0, -0.0, 0.1 + 0.2, f64::MAX] {
            let msg = WireMsg::Cast(Broadcast::bounds(
                1,
                None,
                None,
                Some(Candidate { k: 3, score }),
            ));
            let mut buf = Vec::new();
            encode(&msg, &mut buf);
            let (back, _) = decode_frame(&buf).unwrap();
            match back {
                WireMsg::Cast(b) => {
                    assert_eq!(b.best.unwrap().score.to_bits(), score.to_bits())
                }
                other => panic!("wrong decode: {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_frames_are_typed_errors() {
        let mut buf = Vec::new();
        encode(
            &WireMsg::Cast(Broadcast::bounds(
                0,
                Some(4),
                None,
                Some(Candidate { k: 4, score: 0.5 }),
            )),
            &mut buf,
        );
        // Every proper prefix fails with Truncated — never panics, and
        // never parses as a different message.
        for cut in 0..buf.len() {
            match decode_frame(&buf[..cut]) {
                Err(WireError::Truncated { have, need }) => {
                    assert_eq!(have, cut);
                    assert!(need > cut);
                }
                other => panic!("prefix len {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_and_empty_prefixes_rejected() {
        assert_eq!(
            frame_len((MAX_FRAME_LEN as u32 + 1).to_be_bytes()),
            Err(WireError::Oversized {
                len: MAX_FRAME_LEN + 1
            })
        );
        assert_eq!(
            frame_len(u32::MAX.to_be_bytes()),
            Err(WireError::Oversized {
                len: u32::MAX as usize
            })
        );
        assert!(matches!(
            frame_len(0u32.to_be_bytes()),
            Err(WireError::Corrupt { .. })
        ));
    }

    #[test]
    fn corrupt_tags_and_trailing_bytes_rejected() {
        // Unknown kind.
        assert!(matches!(
            decode_payload(&[99, 0, 0, 0, 0]),
            Err(WireError::Corrupt { .. })
        ));
        // Bad option tag inside a Cast.
        assert!(matches!(
            decode_payload(&[2, 0, 0, 0, 0, 7]),
            Err(WireError::Corrupt { .. })
        ));
        // Valid Hello followed by a stray byte.
        let mut buf = Vec::new();
        encode(&WireMsg::Hello { rank: 1 }, &mut buf);
        let mut payload = buf[4..].to_vec();
        payload.push(0);
        assert_eq!(
            decode_payload(&payload),
            Err(WireError::Corrupt {
                what: "trailing bytes"
            })
        );
    }
}
