//! The pluggable Binary Bleed execution engine (DESIGN.md §3).
//!
//! The paper's central claim is that one pruning schedule (Alg 1/3/4)
//! works identically across serial, multi-thread, multi-rank and
//! distributed regimes. This layer makes that literal in code: a single
//! work loop implements the claim → evaluate → publish → broadcast
//! protocol over the lock-free [`SharedState`](super::state::SharedState),
//! parameterized by three orthogonal axes:
//!
//! | axis        | trait / type          | implementations                          |
//! |-------------|-----------------------|------------------------------------------|
//! | time        | [`Clock`]             | [`WallClock`], [`VirtualClock`]          |
//! | propagation | [`Transport`]         | [`Loopback`], [`MpscNet`], [`SimNet`]    |
//! | work source | [`WorkPlan`]          | serial / ranked / flat chunkings         |
//! | eval cost   | [`EvalCost`]          | [`UnitCost`], `simulate::CostModel`      |
//!
//! The four public entry points are thin configurations:
//!
//! * `binary_bleed_serial`   = threaded driver × 1 worker × [`Loopback`]
//! * `binary_bleed_parallel` = threaded driver × ranks×threads × [`MpscNet`]
//! * `binary_bleed_lockstep` = event driver × [`UnitCost`] × zero latency
//! * `simulate_distributed` / `simulate_parallel_cluster`
//!   = event driver × calibrated [`EvalCost`] × [`SimNet`] latency
//!
//! New regimes (async runtimes, real MPI, elastic resources) are new
//! `Transport`/`Clock` implementations — not fifth and sixth copies of
//! the loop.

pub mod clock;
pub mod core;
pub mod transport;
pub mod work;

pub use self::clock::{duration_from_minutes, Clock, VirtualClock, WallClock};
pub use self::core::{run_event, run_threaded, EvalCost, EvalSpan, EventOutcome, UnitCost};
pub use self::transport::{Loopback, MpscNet, SimNet, Transport};
pub use self::work::{bleed_order, normalize_ks, WorkPlan, WorkerSlot};
