//! The pluggable Binary Bleed execution engine (DESIGN.md §3).
//!
//! The paper's central claim is that one pruning schedule (Alg 1/3/4)
//! works identically across serial, multi-thread, multi-rank and
//! distributed regimes. This layer makes that literal in code: a single
//! work loop implements the claim → evaluate → publish → broadcast
//! protocol over the lock-free [`SharedState`](super::state::SharedState),
//! parameterized by three orthogonal axes:
//!
//! | axis        | trait / type          | implementations                                  |
//! |-------------|-----------------------|--------------------------------------------------|
//! | time        | [`Clock`]             | [`WallClock`], [`VirtualClock`]                  |
//! | propagation | [`Transport`]         | [`Loopback`], [`MpscNet`], [`SimNet`], [`TcpNet`] |
//! | work source | [`WorkPlan`]          | serial / ranked / flat chunkings                 |
//! | eval cost   | [`EvalCost`]          | [`UnitCost`], `simulate::CostModel`              |
//!
//! The four public entry points are thin configurations:
//!
//! * `binary_bleed_serial`   = threaded driver × 1 worker × [`Loopback`]
//! * `binary_bleed_parallel` = threaded driver × ranks×threads × [`MpscNet`]
//! * `binary_bleed_lockstep` = event driver × [`UnitCost`] × zero latency
//! * `simulate_distributed` / `simulate_parallel_cluster`
//!   = event driver × calibrated [`EvalCost`] × [`SimNet`] latency
//!
//! New regimes (async runtimes, real MPI, elastic resources) are new
//! `Transport`/`Clock` implementations — not fifth and sixth copies of
//! the loop.
//!
//! At shutdown the threaded driver folds every rank's candidate
//! optimal — including remote bests a rank rejected as outside its own
//! domain (kept out-of-band by
//! [`SharedState`](super::state::SharedState)) — under the paper's
//! largest-k rule, so heterogeneous-domain runs report a global best.
//!
//! Every entry point is a thin configuration of the same protocol, and
//! they agree on the optimum:
//!
//! ```
//! use binary_bleed::coordinator::{
//!     binary_bleed_lockstep, binary_bleed_serial, Mode, ParallelConfig,
//!     SearchPolicy, Thresholds,
//! };
//! let ks: Vec<u32> = (2..=30).collect();
//! let scorer = |k: u32| if k <= 17 { 0.9 } else { 0.1 };
//! let policy = SearchPolicy::maximize(
//!     Mode::Vanilla,
//!     Thresholds { select: 0.75, stop: 0.2 },
//! );
//! // Threaded driver, one worker, loopback transport (Alg 1).
//! let serial = binary_bleed_serial(&ks, &scorer, policy);
//! assert_eq!(serial.k_optimal, Some(17));
//! // Event driver, unit cost, zero latency: deterministic lockstep
//! // rounds on 2 simulated resources — same optimum.
//! let cfg = ParallelConfig { ranks: 2, ..Default::default() };
//! let lockstep = binary_bleed_lockstep(&ks, &scorer, policy, cfg);
//! assert_eq!(lockstep.k_optimal, Some(17));
//! ```

pub mod clock;
pub mod core;
pub mod tcpnet;
pub mod transport;
pub mod wire;
pub mod work;

pub use self::clock::{duration_from_minutes, Clock, VirtualClock, WallClock};
pub use self::core::{
    run_event, run_event_ev, run_threaded, run_threaded_ev, EvalCost, EvalSpan, EventOutcome,
    UnitCost,
};
pub use self::tcpnet::{TcpBound, TcpFabric, TcpNet, TcpNetConfig, TcpStats};
pub use self::transport::{Loopback, MpscNet, SimNet, Transport};
pub use self::wire::{WireError, WireMsg, MAX_FRAME_LEN};
pub use self::work::{bleed_order, normalize_ks, WorkPlan, WorkerSlot};
