//! L3 coordinator — the paper's contribution (§III).
//!
//! * [`bleed`] — Alg 1: serial Binary Bleed (Vanilla / Early-Stop) plus
//!   the exhaustive Standard baseline.
//! * [`traversal`] — Fig 1: pre/in/post-order BST serialization of K.
//! * [`chunk`] — Alg 2 + Table II: dealing K across resources.
//! * [`state`] — the shared pruning cache (k_min/k_max/optimal).
//! * [`rank`] — BroadcastK / ReceiveKCheck over channel mailboxes.
//! * [`scheduler`] — Alg 3+4: multi-rank multi-thread executors
//!   (real threads and the deterministic lockstep simulation).
//! * [`visit_log`] — the per-k decision record every figure derives from.
//! * [`scorer`] — the `S(f(k, D))` abstraction the engines drive.

pub mod bleed;
pub mod chunk;
pub mod policy;
pub mod rank;
pub mod scheduler;
pub mod scorer;
pub mod state;
pub mod traversal;
pub mod visit_log;

pub use bleed::{binary_bleed_serial, optimal_from_log, standard_search, SearchResult};
pub use chunk::{ChunkStrategy, Pipeline};
pub use policy::{Direction, Mode, SearchPolicy, Thresholds};
pub use rank::{Broadcast, RankComm};
pub use scheduler::{binary_bleed_lockstep, binary_bleed_parallel, ParallelConfig};
pub use scorer::{CountingScorer, KScorer};
pub use state::{Admission, Candidate, SharedState};
pub use traversal::Traversal;
pub use visit_log::{Decision, Visit, VisitLog};
