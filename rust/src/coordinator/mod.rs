//! L3 coordinator — the paper's contribution (§III).
//!
//! * [`engine`] — the pluggable execution core: ONE implementation of
//!   Alg 4's claim → evaluate → publish → broadcast protocol,
//!   parameterized by Clock (wall vs. virtual time), Transport (loopback,
//!   in-proc channels, latency-injecting simulated links, and real
//!   multi-process TCP with a zero-dependency wire codec), WorkPlan
//!   (chunk/traversal front-end) and EvalCost. Every public entry point
//!   below is a thin configuration of it.
//! * [`bleed`] — Alg 1: serial Binary Bleed (Vanilla / Early-Stop) plus
//!   the exhaustive Standard baseline.
//! * [`traversal`] — Fig 1: pre/in/post-order BST serialization of K.
//! * [`chunk`] — Alg 2 + Table II: dealing K across resources.
//! * [`state`] — the shared pruning cache (k_min/k_max/optimal), now
//!   lock-free: atomic bounds + claim bitmap indexed by k-position.
//! * [`rank`] — BroadcastK / ReceiveKCheck over channel mailboxes.
//! * [`scheduler`] — Alg 3+4: multi-rank multi-thread executors
//!   (real threads and the deterministic lockstep replay).
//! * [`visit_log`] — the per-k decision record every figure derives from.
//! * [`scorer`] — the `S(f(k, D))` abstraction the engine drives.
//! * [`evaluation`] — first-class [`Evaluation`] records and the
//!   [`KEvaluator`] trait (scorer adapters included).
//! * [`cache`] — the concurrency-deduplicating [`EvalCache`] between
//!   the engines and the evaluators.
//! * [`session`] — resumable [`SearchSession`]s with JSON checkpoints.
//! * [`fault`] — failure containment (DESIGN.md §3.6): retry policies,
//!   the [`FailSafeEvaluator`] quarantine wrapper, and the
//!   [`FaultPolicy`] knob sessions/CLI expose.

pub mod bleed;
pub mod cache;
pub mod chunk;
pub mod engine;
pub mod evaluation;
pub mod fault;
pub mod policy;
pub mod rank;
pub mod scheduler;
pub mod scorer;
pub mod session;
pub mod state;
pub mod traversal;
pub mod visit_log;

pub use bleed::{binary_bleed_serial, optimal_from_log, standard_search, SearchResult};
pub use cache::{CacheStats, EvalCache};
pub use chunk::{ChunkStrategy, Pipeline};
pub use engine::{
    bleed_order, normalize_ks, run_event_ev, run_threaded_ev, Clock, EvalCost, EvalSpan,
    EventOutcome, Loopback, MpscNet, SimNet, TcpBound, TcpFabric, TcpNet, TcpNetConfig, TcpStats,
    Transport, UnitCost, VirtualClock, WallClock, WireError, WireMsg, WorkPlan, WorkerSlot,
    MAX_FRAME_LEN,
};
pub use evaluation::{
    CountingEvaluator, EvalDiagnostics, EvalError, EvalOutcome, Evaluation, Fingerprint,
    KEvaluator, MetricView, ScorerEvaluator,
};
pub use fault::{FailSafeEvaluator, FaultPolicy, RetryPolicy};
pub use policy::{Direction, Mode, SearchPolicy, Thresholds};
pub use rank::{Broadcast, RankComm};
pub use scheduler::{binary_bleed_lockstep, binary_bleed_parallel, ParallelConfig};
pub use scorer::{CountingScorer, KScorer};
pub use session::{Checkpoint, SearchSession, SessionOutcome, StateSnapshot};
pub use state::{Admission, Candidate, ClaimEvent, SharedState};
pub use traversal::Traversal;
pub use visit_log::{Decision, Visit, VisitLog};
