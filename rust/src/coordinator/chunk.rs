//! Chunking k values across resources (Alg 2, Table II).
//!
//! Two strategies:
//! * `Contiguous` — split the list into `R` consecutive runs (the naive
//!   baseline of Table II's T1/T3, shown by the paper to idle resources);
//! * `SkipMod` — Alg 2: element `i` goes to resource `i mod R`, preserving
//!   sequence order inside each chunk. On a sorted list this deals every
//!   resource a spread of small and large k, so a single selection prunes
//!   work from *every* resource.
//!
//! `Pipeline` composes chunking and traversal-sort in the four orders the
//! paper enumerates (T1–T4) for the Table II ablation.

use super::traversal::Traversal;

/// How to split the k list across resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkStrategy {
    /// T1/T3: consecutive runs, sizes differing by at most one.
    Contiguous,
    /// T2/T4 (Alg 2): position-mod-R dealing.
    SkipMod,
}

impl ChunkStrategy {
    pub fn label(self) -> &'static str {
        match self {
            ChunkStrategy::Contiguous => "contiguous",
            ChunkStrategy::SkipMod => "skip-mod",
        }
    }

    /// Partition `ks` into `resources` chunks.
    pub fn chunk(self, ks: &[u32], resources: usize) -> Vec<Vec<u32>> {
        assert!(resources > 0, "need at least one resource");
        let mut chunks = vec![Vec::new(); resources];
        match self {
            ChunkStrategy::SkipMod => {
                for (i, &k) in ks.iter().enumerate() {
                    chunks[i % resources].push(k);
                }
            }
            ChunkStrategy::Contiguous => {
                let n = ks.len();
                let base = n / resources;
                let extra = n % resources;
                let mut at = 0;
                for (r, chunk) in chunks.iter_mut().enumerate() {
                    let len = base + usize::from(r < extra);
                    chunk.extend_from_slice(&ks[at..at + len]);
                    at += len;
                }
            }
        }
        chunks
    }
}

/// The four chunk/sort composition orders of Table II.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pipeline {
    /// T1: traversal-sort the full list, then contiguous-chunk.
    SortThenContiguous,
    /// T2: traversal-sort the full list, then Alg 2 skip-mod chunk.
    SortThenSkipMod,
    /// T3: contiguous-chunk, then traversal-sort each chunk.
    ContiguousThenSort,
    /// T4: Alg 2 skip-mod chunk, then traversal-sort each chunk
    /// (the paper's recommended composition).
    SkipModThenSort,
}

impl Pipeline {
    pub const ALL: [Pipeline; 4] = [
        Pipeline::SortThenContiguous,
        Pipeline::SortThenSkipMod,
        Pipeline::ContiguousThenSort,
        Pipeline::SkipModThenSort,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Pipeline::SortThenContiguous => "T1 sort->contiguous",
            Pipeline::SortThenSkipMod => "T2 sort->skip-mod",
            Pipeline::ContiguousThenSort => "T3 contiguous->sort",
            Pipeline::SkipModThenSort => "T4 skip-mod->sort",
        }
    }

    /// Produce the per-resource work lists for `ks` (ascending).
    pub fn split(self, ks: &[u32], resources: usize, order: Traversal) -> Vec<Vec<u32>> {
        match self {
            Pipeline::SortThenContiguous => {
                ChunkStrategy::Contiguous.chunk(&order.sort(ks), resources)
            }
            Pipeline::SortThenSkipMod => {
                ChunkStrategy::SkipMod.chunk(&order.sort(ks), resources)
            }
            Pipeline::ContiguousThenSort => ChunkStrategy::Contiguous
                .chunk(ks, resources)
                .into_iter()
                .map(|c| order.sort(&c))
                .collect(),
            Pipeline::SkipModThenSort => ChunkStrategy::SkipMod
                .chunk(ks, resources)
                .into_iter()
                .map(|c| order.sort(&c))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k11() -> Vec<u32> {
        (1..=11).collect()
    }

    #[test]
    fn skip_mod_matches_alg2_example() {
        // Table II T2/T4 input row: [1,3,5,7,9,11] [2,4,6,8,10].
        let chunks = ChunkStrategy::SkipMod.chunk(&k11(), 2);
        assert_eq!(chunks[0], vec![1, 3, 5, 7, 9, 11]);
        assert_eq!(chunks[1], vec![2, 4, 6, 8, 10]);
    }

    #[test]
    fn contiguous_matches_table2_example() {
        let chunks = ChunkStrategy::Contiguous.chunk(&k11(), 2);
        assert_eq!(chunks[0], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(chunks[1], vec![7, 8, 9, 10, 11]);
    }

    #[test]
    fn t1_rows() {
        // Paper Table II T1 Pre: [6,3,2,1,5,4] [9,8,7,11,10].
        let got = Pipeline::SortThenContiguous.split(&k11(), 2, Traversal::PreOrder);
        assert_eq!(got[0], vec![6, 3, 2, 1, 5, 4]);
        assert_eq!(got[1], vec![9, 8, 7, 11, 10]);
    }

    #[test]
    fn t2_rows() {
        // Paper Table II T2 prints value-parity chunks ([3,1,5,9,7,11]
        // [6,2,4,8,10]) which contradicts Alg 2's own position loop
        // (`for k = 0 to Ks-1: resource_id <- k mod R`). We implement
        // Alg 2 as written — deal by *position* in the input sequence,
        // which stays balanced for arbitrary (sparse) K lists. Pinned
        // canonical rows below; discrepancy documented in DESIGN.md §2.4.
        // pre-order full list: [6,3,2,1,5,4,9,8,7,11,10]
        let got = Pipeline::SortThenSkipMod.split(&k11(), 2, Traversal::PreOrder);
        assert_eq!(got[0], vec![6, 2, 5, 9, 7, 10]);
        assert_eq!(got[1], vec![3, 1, 4, 8, 11]);
        // post-order full list: [1,2,4,5,3,7,8,10,11,9,6]
        let post = Pipeline::SortThenSkipMod.split(&k11(), 2, Traversal::PostOrder);
        assert_eq!(post[0], vec![1, 4, 3, 8, 11, 6]);
        assert_eq!(post[1], vec![2, 5, 7, 10, 9]);
    }

    #[test]
    fn t3_rows() {
        // Paper Table II T3 Pre: [4,2,1,3,6,5] [9,8,7,11,10].
        let got = Pipeline::ContiguousThenSort.split(&k11(), 2, Traversal::PreOrder);
        assert_eq!(got[0], vec![4, 2, 1, 3, 6, 5]);
        assert_eq!(got[1], vec![9, 8, 7, 11, 10]);
    }

    #[test]
    fn t4_rows() {
        // Paper Table II T4 Pre: [7,3,1,5,11,9] [6,4,2,10,8].
        let got = Pipeline::SkipModThenSort.split(&k11(), 2, Traversal::PreOrder);
        assert_eq!(got[0], vec![7, 3, 1, 5, 11, 9]);
        assert_eq!(got[1], vec![6, 4, 2, 10, 8]);
        // T4 Post: [1,5,3,9,11,7] [2,4,8,10,6] (paper prints "9" in the
        // second chunk — a typo, 9 lives in chunk 0; see DESIGN.md §2.4).
        let post = Pipeline::SkipModThenSort.split(&k11(), 2, Traversal::PostOrder);
        assert_eq!(post[0], vec![1, 5, 3, 9, 11, 7]);
        assert_eq!(post[1], vec![2, 4, 8, 10, 6]);
    }

    #[test]
    fn chunks_partition_input() {
        for strat in [ChunkStrategy::Contiguous, ChunkStrategy::SkipMod] {
            for r in 1..=7 {
                let ks: Vec<u32> = (2..=30).collect();
                let chunks = strat.chunk(&ks, r);
                assert_eq!(chunks.len(), r);
                let mut all: Vec<u32> = chunks.concat();
                all.sort_unstable();
                assert_eq!(all, ks, "{strat:?} r={r}");
                // Balanced within one element.
                let sizes: Vec<usize> = chunks.iter().map(Vec::len).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "{strat:?} r={r} sizes {sizes:?}");
            }
        }
    }

    #[test]
    fn more_resources_than_ks_leaves_empty_chunks() {
        let chunks = ChunkStrategy::SkipMod.chunk(&[5, 6], 4);
        assert_eq!(chunks[0], vec![5]);
        assert_eq!(chunks[1], vec![6]);
        assert!(chunks[2].is_empty() && chunks[3].is_empty());
    }
}
