//! Evaluator failure containment (DESIGN.md §3.6).
//!
//! The fits the search guards are the dominant cost (Wang/Sun/Bao,
//! PAPERS.md), so a failing fit must be **bounded**: caught at the
//! worker, retried under a seeded deterministic backoff, and after the
//! budget is spent quarantined as a failed k that the search routes
//! around — never an unbounded re-fit loop, and never a panic that
//! takes the whole run down.
//!
//! Layering: engines call [`KEvaluator::try_evaluate`]. By default that
//! is infallible (panics propagate — the crash-then-`--resume` story).
//! Wrapping any evaluator in [`FailSafeEvaluator`] opts into
//! containment:
//!
//! ```text
//! engine → FailSafeEvaluator → EvalCache → model evaluator
//! ```
//!
//! The wrapper sits *above* the cache so a quarantined k costs zero
//! further fits (the quarantine check precedes any cache traffic), and
//! the cache's claim-vacating panic path (`cache.rs`) still lets
//! blocked sharers retake a fit the wrapper is about to retry.
//!
//! Determinism (NUMERICS.md): retries call the same evaluator with the
//! same k — evaluators seed their RNG per (seed, k), so a retried fit
//! that succeeds produces a bitwise-identical record to a first-try
//! success. Backoff delays are a pure function of
//! `(policy.seed, k, attempt)`; they shift wall-clock, never data.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::evaluation::{EvalError, EvalOutcome, Evaluation, Fingerprint, KEvaluator};

/// Seeded deterministic bounded-backoff retry policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total fit attempts per k across *all* workers, including the
    /// first (clamped to ≥ 1).
    pub max_attempts: u32,
    /// Nominal delay before the second attempt; doubles per further
    /// attempt (attempt `a` waits ~`base · 2^(a−2)`).
    pub base_backoff: Duration,
    /// Cap on any single delay. `ZERO` means "cap at `base_backoff`".
    pub max_backoff: Duration,
    /// Jitter seed: the realized delay is the nominal delay scaled by a
    /// hash of `(seed, k, attempt)` into `[0.5, 1.0)` — deterministic,
    /// so a fault run replays with identical pacing.
    pub seed: u64,
}

impl RetryPolicy {
    /// No retries: one attempt, immediate quarantine on failure.
    pub fn none() -> RetryPolicy {
        RetryPolicy::with_attempts(1)
    }

    /// `n` attempts with zero backoff (the testing default — retries
    /// are immediate).
    pub fn with_attempts(n: u32) -> RetryPolicy {
        RetryPolicy {
            max_attempts: n.max(1),
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            seed: 0,
        }
    }

    fn attempts(&self) -> u32 {
        self.max_attempts.max(1)
    }

    /// Delay to sleep before the given attempt (1-based; the first
    /// attempt never waits). Pure function of `(seed, k, attempt)`.
    pub fn backoff_before(&self, k: u32, attempt: u32) -> Duration {
        if attempt <= 1 || self.base_backoff.is_zero() {
            return Duration::ZERO;
        }
        let cap = if self.max_backoff.is_zero() {
            self.base_backoff
        } else {
            self.max_backoff
        };
        let exp = (attempt - 2).min(20);
        let nominal = self.base_backoff.saturating_mul(1u32 << exp).min(cap);
        // Jitter into [0.5, 1.0) of nominal: decorrelates racing
        // workers without losing replayability.
        let h = splitmix64(self.seed ^ (u64::from(k) << 32) ^ u64::from(attempt));
        let frac = 0.5 + 0.5 * ((h >> 11) as f64 / (1u64 << 53) as f64);
        nominal.mul_f64(frac)
    }
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(250),
            seed: 0,
        }
    }
}

/// Session-level fault-tolerance switches
/// ([`SearchSession::with_faults`](super::session::SearchSession::with_faults)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultPolicy {
    /// Evaluator-level containment: panics/errors are caught, retried
    /// and quarantined under this policy. `None` leaves evaluator
    /// panics free to kill their worker (the lease layer then contains
    /// the *worker* death instead).
    pub retry: Option<RetryPolicy>,
    /// Claim-lease TTL in lease-clock ticks
    /// ([`SharedState::with_leases`](super::state::SharedState::with_leases));
    /// `0` disables leases (claims are permanent, worker panics
    /// propagate out of the engine).
    pub lease_ttl: u64,
}

impl FaultPolicy {
    /// Everything on: 3 bounded-backoff attempts per k, leases with a
    /// 16-tick TTL.
    pub fn tolerant() -> FaultPolicy {
        FaultPolicy {
            retry: Some(RetryPolicy::default()),
            lease_ttl: 16,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.retry.is_some() || self.lease_ttl > 0
    }
}

/// Per-k containment record in the shared ledger.
#[derive(Default)]
struct AttemptState {
    /// Fit attempts consumed so far, *across all workers*.
    attempts: u32,
    /// Set once any attempt succeeds: later callers go straight through
    /// (a cache hit underneath — zero extra fits).
    succeeded: bool,
    /// Set once the budget is spent: the k is failed, permanently.
    quarantined: Option<EvalError>,
}

/// Worker-side failure containment: catches panics and `EvalError`s
/// from the wrapped evaluator, retries under [`RetryPolicy`], and
/// quarantines ks that exhaust their budget. The attempt ledger is
/// shared by every worker, so the `max_attempts` bound is **global**
/// per k — racing workers driving the same k cannot multiply the
/// budget into a retry storm.
///
/// Non-finite scores are treated as failed attempts: a NaN score can
/// never be selected, so under containment it is retried (models seed
/// per-(seed, k): a deterministic NaN quarantines after the budget).
pub struct FailSafeEvaluator<'a> {
    inner: &'a dyn KEvaluator,
    policy: RetryPolicy,
    ledger: Mutex<BTreeMap<u32, AttemptState>>,
    /// Signaled whenever a k reaches a verdict (success or quarantine)
    /// so callers parked on an exhausted-but-undecided budget wake.
    changed: Condvar,
}

impl<'a> FailSafeEvaluator<'a> {
    pub fn new(inner: &'a dyn KEvaluator, policy: RetryPolicy) -> FailSafeEvaluator<'a> {
        FailSafeEvaluator {
            inner,
            policy,
            ledger: Mutex::new(BTreeMap::new()),
            changed: Condvar::new(),
        }
    }

    /// The quarantined failures, ascending k.
    pub fn failures(&self) -> Vec<EvalError> {
        let ledger = self.ledger.lock().unwrap();
        ledger
            .values()
            .filter_map(|st| st.quarantined.clone())
            .collect()
    }

    /// Preload quarantined ks (checkpoint `failed` records) so a
    /// resumed session reports them without spending a single fit on
    /// re-proving the failure.
    pub fn preload_failures(&self, errs: impl IntoIterator<Item = EvalError>) {
        let mut ledger = self.ledger.lock().unwrap();
        for err in errs {
            let st = ledger.entry(err.k).or_default();
            if !st.succeeded && st.quarantined.is_none() {
                st.attempts = st.attempts.max(err.attempts);
                st.quarantined = Some(err);
            }
        }
    }

    /// One contained attempt: panic, explicit `Err`, and non-finite
    /// scores all normalize to `Err(reason)`.
    fn attempt(&self, k: u32) -> Result<Evaluation, String> {
        match catch_unwind(AssertUnwindSafe(|| self.inner.try_evaluate(k))) {
            Ok(Ok(rec)) => {
                if rec.score.is_finite() {
                    Ok(rec)
                } else {
                    Err(format!("non-finite score {}", rec.score))
                }
            }
            Ok(Err(err)) => Err(err.reason),
            Err(payload) => Err(format!("panic: {}", panic_message(&payload))),
        }
    }
}

impl KEvaluator for FailSafeEvaluator<'_> {
    /// Infallible entry: only sound for ks that cannot be quarantined.
    /// A quarantined k has no record to return, so this panics with the
    /// quarantine verdict — engines go through `try_evaluate`.
    fn evaluate(&self, k: u32) -> Evaluation {
        self.try_evaluate(k)
            .unwrap_or_else(|err| panic!("quarantined evaluation requested infallibly: {err}"))
    }

    fn try_evaluate(&self, k: u32) -> EvalOutcome {
        loop {
            // Admission to one attempt, under the global per-k budget.
            let attempt = {
                let mut ledger = self.ledger.lock().unwrap();
                loop {
                    let st = ledger.entry(k).or_default();
                    if let Some(err) = &st.quarantined {
                        return Err(err.clone());
                    }
                    if st.succeeded {
                        // Another worker already proved the fit: the
                        // call below is a cache hit, not a new attempt.
                        drop(ledger);
                        return self.inner.try_evaluate(k);
                    }
                    if st.attempts < self.policy.attempts() {
                        st.attempts += 1;
                        break st.attempts;
                    }
                    // Budget spent but undecided: the final attempt is
                    // in flight on another worker. Wait for its verdict
                    // (it always sets `succeeded` or `quarantined`).
                    ledger = self.changed.wait(ledger).unwrap();
                }
            };
            let delay = self.policy.backoff_before(k, attempt);
            if !delay.is_zero() {
                std::thread::sleep(delay);
            }
            match self.attempt(k) {
                Ok(rec) => {
                    let mut ledger = self.ledger.lock().unwrap();
                    ledger.entry(k).or_default().succeeded = true;
                    drop(ledger);
                    self.changed.notify_all();
                    return Ok(rec);
                }
                Err(reason) => {
                    let mut ledger = self.ledger.lock().unwrap();
                    let st = ledger.entry(k).or_default();
                    if st.succeeded {
                        // A racing worker won with a good fit while ours
                        // failed; serve the shared record.
                        drop(ledger);
                        return self.inner.try_evaluate(k);
                    }
                    if st.attempts >= self.policy.attempts() && st.quarantined.is_none() {
                        st.quarantined = Some(EvalError {
                            k,
                            attempts: st.attempts,
                            reason,
                        });
                    }
                    if let Some(err) = &st.quarantined {
                        let err = err.clone();
                        drop(ledger);
                        self.changed.notify_all();
                        return Err(err);
                    }
                    // Budget remains: loop for another admission.
                }
            }
        }
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn fingerprint(&self) -> Fingerprint {
        self.inner.fingerprint()
    }
}

/// Render a panic payload: `&str` and `String` payloads verbatim
/// (covers `panic!`/`assert!`), anything else opaquely.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Panics for `k` the first `panics` times it is asked, then
    /// succeeds; always errors for ks in `poison`.
    struct Flaky {
        panics: AtomicU64,
        victim: u32,
        poison: Vec<u32>,
    }

    impl KEvaluator for Flaky {
        fn evaluate(&self, k: u32) -> Evaluation {
            // ORDER: Relaxed — test bookkeeping only.
            if k == self.victim && self.panics.load(Ordering::Relaxed) > 0 {
                self.panics.fetch_sub(1, Ordering::Relaxed);
                panic!("flaky fit k={k}");
            }
            assert!(!self.poison.contains(&k), "poisoned k reached evaluate");
            Evaluation::scalar(k, f64::from(k))
        }

        fn try_evaluate(&self, k: u32) -> EvalOutcome {
            if self.poison.contains(&k) {
                return Err(EvalError {
                    k,
                    attempts: 1,
                    reason: "poisoned".into(),
                });
            }
            Ok(self.evaluate(k))
        }
    }

    #[test]
    fn retries_then_succeeds_within_budget() {
        let flaky = Flaky {
            panics: AtomicU64::new(2),
            victim: 7,
            poison: vec![],
        };
        let safe = FailSafeEvaluator::new(&flaky, RetryPolicy::with_attempts(3));
        let rec = safe.try_evaluate(7).expect("third attempt succeeds");
        assert_eq!(rec.score, 7.0);
        assert!(safe.failures().is_empty());
    }

    #[test]
    fn exhausted_budget_quarantines_and_sticks() {
        let flaky = Flaky {
            panics: AtomicU64::new(10),
            victim: 5,
            poison: vec![9],
        };
        let safe = FailSafeEvaluator::new(&flaky, RetryPolicy::with_attempts(2));
        let err = safe.try_evaluate(5).expect_err("budget of 2 exhausted");
        assert_eq!((err.k, err.attempts), (5, 2));
        assert!(err.reason.contains("flaky fit"), "{}", err.reason);
        // Quarantine is sticky and costs zero further fits: the inner
        // panic counter does not move again.
        // ORDER: Relaxed — test bookkeeping only.
        let left = flaky.panics.load(Ordering::Relaxed);
        let again = safe.try_evaluate(5).expect_err("still quarantined");
        assert_eq!(again, err);
        assert_eq!(flaky.panics.load(Ordering::Relaxed), left);
        // Explicit Err paths quarantine too, with the evaluator's text.
        let poisoned = safe.try_evaluate(9).expect_err("poisoned k fails");
        assert_eq!(poisoned.reason, "poisoned");
        let failed: Vec<u32> = safe.failures().iter().map(|e| e.k).collect();
        assert_eq!(failed, vec![5, 9]);
    }

    #[test]
    fn racing_workers_share_one_global_budget() {
        // 8 workers hammer one always-failing k under max_attempts=3:
        // the inner evaluator must be hit at most 3 times in total.
        struct CountErr {
            calls: AtomicU64,
        }
        impl KEvaluator for CountErr {
            fn evaluate(&self, _k: u32) -> Evaluation {
                unreachable!("try_evaluate only")
            }
            fn try_evaluate(&self, k: u32) -> EvalOutcome {
                // ORDER: Relaxed — test bookkeeping only.
                self.calls.fetch_add(1, Ordering::Relaxed);
                Err(EvalError {
                    k,
                    attempts: 1,
                    reason: "always fails".into(),
                })
            }
        }
        let inner = CountErr {
            calls: AtomicU64::new(0),
        };
        let safe = FailSafeEvaluator::new(&inner, RetryPolicy::with_attempts(3));
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let err = safe.try_evaluate(4).expect_err("always fails");
                    assert_eq!(err.k, 4);
                });
            }
        });
        // ORDER: Relaxed — read after join; the join is the edge.
        assert!(inner.calls.load(Ordering::Relaxed) <= 3);
        assert_eq!(safe.failures().len(), 1);
    }

    #[test]
    fn preloaded_failures_skip_refits() {
        let flaky = Flaky {
            panics: AtomicU64::new(0),
            victim: 0,
            poison: vec![],
        };
        let safe = FailSafeEvaluator::new(&flaky, RetryPolicy::with_attempts(3));
        safe.preload_failures([EvalError {
            k: 11,
            attempts: 3,
            reason: "from checkpoint".into(),
        }]);
        let err = safe.try_evaluate(11).expect_err("preloaded quarantine");
        assert_eq!(err.reason, "from checkpoint");
        // Other ks are unaffected.
        assert_eq!(safe.try_evaluate(3).unwrap().score, 3.0);
    }

    #[test]
    fn non_finite_scores_are_contained_failures() {
        struct NanAt13;
        impl KEvaluator for NanAt13 {
            fn evaluate(&self, k: u32) -> Evaluation {
                let score = if k == 13 { f64::NAN } else { f64::from(k) };
                Evaluation::scalar(k, score)
            }
        }
        let inner = NanAt13;
        let safe = FailSafeEvaluator::new(&inner, RetryPolicy::with_attempts(2));
        let err = safe.try_evaluate(13).expect_err("NaN is a failure");
        assert!(err.reason.contains("non-finite"), "{}", err.reason);
        assert_eq!(safe.try_evaluate(12).unwrap().score, 12.0);
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_grows() {
        let p = RetryPolicy {
            max_attempts: 5,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(40),
            seed: 0xFA11,
        };
        // First attempt never waits.
        assert_eq!(p.backoff_before(9, 1), Duration::ZERO);
        let d2 = p.backoff_before(9, 2);
        let d3 = p.backoff_before(9, 3);
        let d4 = p.backoff_before(9, 4);
        // Jitter keeps each delay within [nominal/2, nominal], nominal
        // doubling then capping.
        assert!(d2 >= Duration::from_millis(5) && d2 <= Duration::from_millis(10));
        assert!(d3 >= Duration::from_millis(10) && d3 <= Duration::from_millis(20));
        assert!(d4 >= Duration::from_millis(20) && d4 <= Duration::from_millis(40));
        // Replayable: same (seed, k, attempt) → same delay; different k
        // decorrelates.
        assert_eq!(d2, p.backoff_before(9, 2));
        assert_ne!(p.backoff_before(9, 2), p.backoff_before(10, 2));
        // Zero-backoff policies never sleep.
        assert_eq!(
            RetryPolicy::with_attempts(4).backoff_before(9, 3),
            Duration::ZERO
        );
    }
}
