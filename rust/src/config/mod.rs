//! Typed experiment configuration (DESIGN.md S14): presets + TOML files.
//!
//! Every experiment runner takes an [`ExperimentConfig`]; `quick` (CI
//! budget) and `paper` (full §IV scale) presets are built in and any field
//! can be overridden from a `configs/*.toml` file or CLI flags.

pub mod toml;

use crate::linalg::KMeansAlgo;
use crate::util::error::{anyhow, bail, ensure, Context, Result};
use crate::util::simd::SimdPolicy;

use crate::coordinator::{
    Mode, ParallelConfig, Pipeline, SearchPolicy, Thresholds, Traversal,
};

pub use toml::{parse_toml, TomlValue};

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// RNG seed for data generation + model inits.
    pub seed: u64,
    /// Search space: K = {k_min .. k_max} inclusive.
    pub k_min: u32,
    pub k_max: u32,
    /// Thresholds for the select/stop heuristics.
    pub thresholds: Thresholds,
    /// Parallel shape.
    pub ranks: usize,
    pub threads_per_rank: usize,
    /// Intra-evaluation thread budget per model fit (§3.2). `0` = auto:
    /// divide the host's hardware threads by the engine worker count so
    /// the product never oversubscribes the machine.
    pub eval_threads: usize,
    /// Concurrent outer tasks per evaluation (§3.2 two-level rule):
    /// NMFk/RESCALk perturbations and K-means restarts run as tasks on
    /// the eval-thread pool, with outer × inner kernel threads never
    /// exceeding `eval_threads`. `0` = auto (as many tasks as the
    /// budget allows), `1` = sequential. Scores are bitwise identical
    /// under every setting.
    pub outer_tasks: usize,
    /// SIMD dispatch policy for the native evaluation kernels
    /// (NUMERICS.md): `auto` (default, vector on), `scalar` (the
    /// pre-SIMD oracle loops), `vector`. Installed process-globally by
    /// [`ExperimentConfig::install_simd`]; TOML `parallel.simd`, CLI
    /// `--simd`.
    pub simd: SimdPolicy,
    pub traversal: Traversal,
    pub pipeline: Pipeline,
    /// Sweep density for figure experiments: evaluate every `stride`-th
    /// k_true (quick preset thins the §IV sweeps).
    pub sweep_stride: usize,
    /// NMFk trials: perturbations per k.
    pub perturbations: usize,
    /// K-means restarts per k.
    pub restarts: usize,
    /// K-means assignment algorithm for the native backend
    /// (NUMERICS.md): `lloyd` (the bitwise oracle), the bound-based
    /// `hamerly` | `elkan` | `yinyang`, or `auto` (default — pick per
    /// (n, d, k) shape). TOML `model.kmeans_algo`, CLI `--kmeans-algo`.
    pub kmeans_algo: KMeansAlgo,
    /// Where results (csv/md) land.
    pub results_dir: String,
    /// Human label.
    pub preset: String,
    /// Session checkpoint file for `bleed search` (DESIGN.md S22):
    /// completed evaluation records are journaled here as they finish,
    /// and the pruning-state snapshot + visit log land at shutdown.
    /// TOML `session.checkpoint`, CLI `--checkpoint`.
    pub checkpoint: Option<String>,
    /// Warm-start from the checkpoint (skip already-fitted k). TOML
    /// `session.resume`, CLI `--resume`.
    pub resume: bool,
    /// Evaluator-failure containment (DESIGN.md §3.6): total fit
    /// attempts per k before quarantine. `1` disables retries (a second
    /// attempt never happens); paired with `retry_backoff_ms` for the
    /// delay schedule. TOML `fault.max_attempts`, CLI `--max-attempts`.
    pub max_attempts: u32,
    /// Nominal backoff before the second attempt, doubling per further
    /// attempt (deterministically jittered from the run seed). TOML
    /// `fault.backoff_ms`, CLI `--retry-backoff-ms`.
    pub retry_backoff_ms: u64,
    /// Claim-lease TTL in lease-clock ticks; `0` = permanent claims (no
    /// worker-death recovery). TOML `fault.lease_ttl`, CLI
    /// `--lease-ttl`.
    pub lease_ttl: u64,
    /// Multi-process cluster (DESIGN.md §3.7): one `host:port` listen
    /// address per rank. Non-empty turns `bleed search` into an
    /// orchestrator that self-spawns one `bleed worker` process per
    /// rank over TCP. TOML `cluster.ranks` (array of strings, or one
    /// comma-separated string), CLI `--ranks host1:p1,host2:p2`.
    pub cluster_ranks: Vec<String>,
    /// TCP heartbeat period in milliseconds: each beat renews held
    /// claim leases and redials dead links; `0` disables the heartbeat
    /// thread (then a dead process's leases never expire). TOML
    /// `cluster.heartbeat_ms`, CLI `--heartbeat-ms`.
    pub heartbeat_ms: u64,
    /// Out-of-core dataset (DESIGN.md §3.8): path to a `.bbm` tiled
    /// matrix to search instead of generating a synthetic dataset.
    /// `None` = in-memory synthetic data. TOML `data.path`, CLI
    /// `--data`.
    pub data_path: Option<String>,
    /// Prefetch window (tiles in flight) for the out-of-core reader:
    /// `0` = synchronous reads, `n` = the consumer runs up to `n` tiles
    /// behind the prefetcher. Results are bitwise identical at every
    /// depth. TOML `data.prefetch_tiles`, CLI `--prefetch-tiles`.
    pub prefetch_tiles: usize,
}

impl ExperimentConfig {
    /// CI/laptop preset — minutes, not hours.
    pub fn quick() -> Self {
        Self {
            seed: 0xB1EED,
            k_min: 2,
            k_max: 30,
            thresholds: Thresholds {
                select: 0.75,
                stop: 0.2,
            },
            ranks: 2,
            threads_per_rank: 2,
            eval_threads: 0,
            outer_tasks: 0,
            simd: SimdPolicy::Auto,
            traversal: Traversal::PreOrder,
            pipeline: Pipeline::SkipModThenSort,
            sweep_stride: 4,
            perturbations: 3,
            restarts: 2,
            kmeans_algo: KMeansAlgo::Auto,
            results_dir: "results".into(),
            preset: "quick".into(),
            checkpoint: None,
            resume: false,
            max_attempts: 1,
            retry_backoff_ms: 10,
            lease_ttl: 0,
            cluster_ranks: Vec::new(),
            heartbeat_ms: 25,
            data_path: None,
            prefetch_tiles: 2,
        }
    }

    /// Paper-scale preset (§IV-A sweeps every k_true).
    pub fn paper() -> Self {
        Self {
            sweep_stride: 1,
            perturbations: 6,
            restarts: 5,
            preset: "paper".into(),
            ..Self::quick()
        }
    }

    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "quick" => Ok(Self::quick()),
            "paper" => Ok(Self::paper()),
            other => bail!("unknown preset '{other}' (quick|paper)"),
        }
    }

    /// The searched k list.
    pub fn ks(&self) -> Vec<u32> {
        (self.k_min..=self.k_max).collect()
    }

    /// Policy for a given mode, inheriting the config thresholds.
    pub fn policy(&self, mode: Mode) -> SearchPolicy {
        SearchPolicy::maximize(mode, self.thresholds)
    }

    /// The effective intra-evaluation thread budget: the explicit
    /// `eval_threads` when set, otherwise hardware threads divided by
    /// the engine worker count (`ranks × threads_per_rank`) so the
    /// product never oversubscribes the machine (§3.2).
    pub fn resolved_eval_threads(&self) -> usize {
        if self.eval_threads != 0 {
            return self.eval_threads;
        }
        crate::util::pool::eval_thread_budget(
            crate::util::pool::available_threads(),
            self.engine_workers(),
        )
    }

    /// Concurrent engine workers (`ranks × threads_per_rank`) — the
    /// submitter count the shared evaluator's persistent pool is sized
    /// for (`ThreadPool::for_submitters`).
    pub fn engine_workers(&self) -> usize {
        self.ranks.max(1) * self.threads_per_rank.max(1)
    }

    /// Install this config's SIMD policy as the process-global kernel
    /// dispatch (`util::simd::set_simd_policy`). Experiment and search
    /// entry points call this once before evaluating anything, so every
    /// kernel of the run dispatches consistently.
    pub fn install_simd(&self) {
        crate::util::simd::set_simd_policy(self.simd);
    }

    /// Fault policy for search sessions (DESIGN.md §3.6): retries are
    /// on when `max_attempts > 1`, claim leases when `lease_ttl > 0`.
    /// The retry jitter is seeded from the run seed, so a re-run
    /// reproduces the same backoff schedule.
    pub fn faults(&self) -> crate::coordinator::FaultPolicy {
        use crate::coordinator::{FaultPolicy, RetryPolicy};
        let retry = (self.max_attempts > 1).then(|| RetryPolicy {
            max_attempts: self.max_attempts,
            base_backoff: std::time::Duration::from_millis(self.retry_backoff_ms),
            max_backoff: std::time::Duration::from_millis(
                self.retry_backoff_ms.saturating_mul(25),
            ),
            seed: self.seed,
        });
        FaultPolicy {
            retry,
            lease_ttl: self.lease_ttl,
        }
    }

    /// Parallel config for the scheduler.
    pub fn parallel(&self) -> ParallelConfig {
        ParallelConfig {
            ranks: self.ranks,
            threads_per_rank: self.threads_per_rank,
            traversal: self.traversal,
            pipeline: self.pipeline,
        }
    }

    /// Load overrides from a TOML file on top of a preset base.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let t = parse_toml(&text).with_context(|| format!("parsing {path}"))?;
        let base = t
            .get("preset")
            .and_then(TomlValue::as_str)
            .unwrap_or("quick");
        let mut cfg = Self::by_name(base)?;
        cfg.apply_toml(&t)?;
        Ok(cfg)
    }

    /// Apply overrides from a parsed TOML table.
    pub fn apply_toml(&mut self, t: &TomlValue) -> Result<()> {
        if let Some(v) = t.get("seed").and_then(TomlValue::as_int) {
            self.seed = v as u64;
        }
        if let Some(v) = t.get_path("search.k_min").and_then(TomlValue::as_int) {
            self.k_min = v as u32;
        }
        if let Some(v) = t.get_path("search.k_max").and_then(TomlValue::as_int) {
            self.k_max = v as u32;
        }
        if let Some(v) = t
            .get_path("search.select_threshold")
            .and_then(TomlValue::as_float)
        {
            self.thresholds.select = v;
        }
        if let Some(v) = t
            .get_path("search.stop_threshold")
            .and_then(TomlValue::as_float)
        {
            self.thresholds.stop = v;
        }
        if let Some(v) = t.get_path("search.order").and_then(TomlValue::as_str) {
            self.traversal = parse_traversal(v)?;
        }
        if let Some(v) = t.get_path("parallel.ranks").and_then(TomlValue::as_int) {
            self.ranks = v as usize;
        }
        if let Some(v) = t
            .get_path("parallel.threads_per_rank")
            .and_then(TomlValue::as_int)
        {
            self.threads_per_rank = v as usize;
        }
        if let Some(v) = t
            .get_path("parallel.eval_threads")
            .and_then(TomlValue::as_int)
        {
            // Clamp instead of `as usize`: a negative value would wrap
            // to an astronomical thread budget. Negative ⇒ 0 ⇒ auto.
            self.eval_threads = v.max(0) as usize;
        }
        if let Some(v) = t
            .get_path("parallel.outer_tasks")
            .and_then(TomlValue::as_int)
        {
            // Same clamp as eval_threads: negative ⇒ 0 ⇒ auto.
            self.outer_tasks = v.max(0) as usize;
        }
        if let Some(v) = t.get_path("parallel.simd").and_then(TomlValue::as_str) {
            self.simd = parse_simd(v)?;
        }
        if let Some(v) = t.get_path("parallel.pipeline").and_then(TomlValue::as_str) {
            self.pipeline = parse_pipeline(v)?;
        }
        if let Some(v) = t.get_path("sweep.stride").and_then(TomlValue::as_int) {
            self.sweep_stride = (v as usize).max(1);
        }
        if let Some(v) = t
            .get_path("model.perturbations")
            .and_then(TomlValue::as_int)
        {
            self.perturbations = v as usize;
        }
        if let Some(v) = t.get_path("model.restarts").and_then(TomlValue::as_int) {
            self.restarts = v as usize;
        }
        if let Some(v) = t
            .get_path("model.kmeans_algo")
            .and_then(TomlValue::as_str)
        {
            self.kmeans_algo = parse_kmeans_algo(v)?;
        }
        if let Some(v) = t.get("results_dir").and_then(TomlValue::as_str) {
            self.results_dir = v.to_string();
        }
        if let Some(v) = t
            .get_path("session.checkpoint")
            .and_then(TomlValue::as_str)
        {
            self.checkpoint = Some(v.to_string());
        }
        if let Some(v) = t.get_path("session.resume").and_then(TomlValue::as_bool) {
            self.resume = v;
        }
        if let Some(v) = t.get_path("fault.max_attempts").and_then(TomlValue::as_int) {
            // Clamp: zero/negative would mean "never even try once".
            self.max_attempts = v.max(1) as u32;
        }
        if let Some(v) = t.get_path("fault.backoff_ms").and_then(TomlValue::as_int) {
            self.retry_backoff_ms = v.max(0) as u64;
        }
        if let Some(v) = t.get_path("fault.lease_ttl").and_then(TomlValue::as_int) {
            self.lease_ttl = v.max(0) as u64;
        }
        if let Some(v) = t.get_path("cluster.ranks") {
            // Either an array of "host:port" strings or one
            // comma-separated string — both forms appear in the wild.
            self.cluster_ranks = match v {
                TomlValue::Array(items) => items
                    .iter()
                    .map(|it| {
                        it.as_str()
                            .map(str::to_string)
                            .context("cluster.ranks entries must be \"host:port\" strings")
                    })
                    .collect::<Result<Vec<String>>>()?,
                TomlValue::Str(s) => s
                    .split(',')
                    .map(str::trim)
                    .filter(|p| !p.is_empty())
                    .map(str::to_string)
                    .collect(),
                _ => bail!("cluster.ranks must be an array or comma string"),
            };
        }
        if let Some(v) = t.get_path("cluster.heartbeat_ms").and_then(TomlValue::as_int) {
            self.heartbeat_ms = v.max(0) as u64;
        }
        if let Some(v) = t.get_path("data.path").and_then(TomlValue::as_str) {
            self.data_path = Some(v.to_string());
        }
        if let Some(v) = t
            .get_path("data.prefetch_tiles")
            .and_then(TomlValue::as_int)
        {
            // Same clamp as eval_threads: negative ⇒ 0 ⇒ synchronous.
            self.prefetch_tiles = v.max(0) as usize;
        }
        ensure!(self.k_min >= 1 && self.k_min <= self.k_max, "bad k range");
        Ok(())
    }
}

/// Parse a traversal label ("pre" | "post" | "in").
pub fn parse_traversal(s: &str) -> Result<Traversal> {
    Ok(match s {
        "pre" | "pre-order" => Traversal::PreOrder,
        "post" | "post-order" => Traversal::PostOrder,
        "in" | "in-order" => Traversal::InOrder,
        other => bail!("unknown traversal '{other}' (pre|post|in)"),
    })
}

/// Parse a mode label.
pub fn parse_mode(s: &str) -> Result<Mode> {
    Ok(match s {
        "standard" => Mode::Standard,
        "vanilla" => Mode::Vanilla,
        "early-stop" | "earlystop" | "es" => Mode::EarlyStop,
        other => bail!("unknown mode '{other}' (standard|vanilla|early-stop)"),
    })
}

/// Parse a SIMD policy label ("auto" | "scalar" | "vector").
pub fn parse_simd(s: &str) -> Result<SimdPolicy> {
    s.parse::<SimdPolicy>().map_err(|e| anyhow!("{e}"))
}

/// Parse a k-means algorithm label
/// ("lloyd" | "hamerly" | "elkan" | "yinyang" | "auto").
pub fn parse_kmeans_algo(s: &str) -> Result<KMeansAlgo> {
    s.parse::<KMeansAlgo>().map_err(|e| anyhow!("{e}"))
}

/// Parse a Table II pipeline label.
pub fn parse_pipeline(s: &str) -> Result<Pipeline> {
    Ok(match s {
        "t1" | "sort-contiguous" => Pipeline::SortThenContiguous,
        "t2" | "sort-skipmod" => Pipeline::SortThenSkipMod,
        "t3" | "contiguous-sort" => Pipeline::ContiguousThenSort,
        "t4" | "skipmod-sort" => Pipeline::SkipModThenSort,
        other => bail!("unknown pipeline '{other}' (t1|t2|t3|t4)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_in_scale() {
        let q = ExperimentConfig::quick();
        let p = ExperimentConfig::paper();
        assert!(q.sweep_stride > p.sweep_stride);
        assert!(q.perturbations < p.perturbations);
        assert_eq!(q.ks().len(), 29);
    }

    #[test]
    fn toml_overrides_apply() {
        let doc = r#"
preset = "quick"
seed = 7
[search]
k_max = 50
select_threshold = 0.8
order = "post"
[parallel]
ranks = 8
eval_threads = 3
outer_tasks = 2
simd = "scalar"
pipeline = "t2"
[sweep]
stride = 2
"#;
        let mut cfg = ExperimentConfig::quick();
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.k_max, 50);
        assert_eq!(cfg.thresholds.select, 0.8);
        assert_eq!(cfg.traversal, Traversal::PostOrder);
        assert_eq!(cfg.ranks, 8);
        assert_eq!(cfg.eval_threads, 3);
        assert_eq!(cfg.resolved_eval_threads(), 3);
        assert_eq!(cfg.outer_tasks, 2);
        assert_eq!(cfg.simd, SimdPolicy::ForceScalar);
        assert_eq!(cfg.pipeline, Pipeline::SortThenSkipMod);
        assert_eq!(cfg.sweep_stride, 2);
    }

    #[test]
    fn session_toml_overrides_apply() {
        let mut cfg = ExperimentConfig::quick();
        assert_eq!(cfg.checkpoint, None);
        assert!(!cfg.resume);
        let doc = "[session]\ncheckpoint = \"runs/search.ckpt.json\"\nresume = true\n";
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.checkpoint.as_deref(), Some("runs/search.ckpt.json"));
        assert!(cfg.resume);
    }

    #[test]
    fn fault_toml_overrides_apply() {
        let mut cfg = ExperimentConfig::quick();
        // Defaults: no containment, no leases.
        assert!(!cfg.faults().is_enabled());
        let doc = "[fault]\nmax_attempts = 4\nbackoff_ms = 5\nlease_ttl = 16\n";
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.max_attempts, 4);
        assert_eq!(cfg.retry_backoff_ms, 5);
        assert_eq!(cfg.lease_ttl, 16);
        let faults = cfg.faults();
        assert!(faults.is_enabled());
        assert_eq!(faults.lease_ttl, 16);
        let retry = faults.retry.unwrap();
        assert_eq!(retry.max_attempts, 4);
        assert_eq!(retry.seed, cfg.seed, "jitter is seeded from the run seed");
        // Clamps: attempts never below one fit.
        let mut cfg = ExperimentConfig::quick();
        cfg.apply_toml(&parse_toml("[fault]\nmax_attempts = 0\n").unwrap())
            .unwrap();
        assert_eq!(cfg.max_attempts, 1);
        assert!(cfg.faults().retry.is_none(), "one attempt = no retry layer");
    }

    #[test]
    fn cluster_toml_overrides_apply() {
        let mut cfg = ExperimentConfig::quick();
        assert!(cfg.cluster_ranks.is_empty(), "single-process by default");
        assert_eq!(cfg.heartbeat_ms, 25);
        let doc = "[cluster]\nranks = [\"127.0.0.1:7401\", \"127.0.0.1:7402\"]\nheartbeat_ms = 10\n";
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.cluster_ranks, vec!["127.0.0.1:7401", "127.0.0.1:7402"]);
        assert_eq!(cfg.heartbeat_ms, 10);
        // Comma-string form parses to the same list.
        let mut cfg = ExperimentConfig::quick();
        let doc = "[cluster]\nranks = \"127.0.0.1:7401, 127.0.0.1:7402\"\n";
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.cluster_ranks, vec!["127.0.0.1:7401", "127.0.0.1:7402"]);
        // Non-string entries are rejected with a typed error.
        let mut cfg = ExperimentConfig::quick();
        assert!(cfg
            .apply_toml(&parse_toml("[cluster]\nranks = [7401, 7402]\n").unwrap())
            .is_err());
    }

    #[test]
    fn data_toml_overrides_apply() {
        let mut cfg = ExperimentConfig::quick();
        assert_eq!(cfg.data_path, None, "synthetic data by default");
        assert_eq!(cfg.prefetch_tiles, 2);
        let doc = "[data]\npath = \"data/big.bbm\"\nprefetch_tiles = 4\n";
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.data_path.as_deref(), Some("data/big.bbm"));
        assert_eq!(cfg.prefetch_tiles, 4);
        // Negative depth clamps to synchronous, not a wrapped usize.
        cfg.apply_toml(&parse_toml("[data]\nprefetch_tiles = -3\n").unwrap())
            .unwrap();
        assert_eq!(cfg.prefetch_tiles, 0);
    }

    #[test]
    fn simd_defaults_to_auto_and_rejects_bad_labels() {
        assert_eq!(ExperimentConfig::quick().simd, SimdPolicy::Auto);
        assert_eq!(parse_simd("vector").unwrap(), SimdPolicy::ForceVector);
        assert!(parse_simd("warp").is_err());
        let mut cfg = ExperimentConfig::quick();
        assert!(cfg
            .apply_toml(&parse_toml("[parallel]\nsimd = \"mmx\"\n").unwrap())
            .is_err());
    }

    #[test]
    fn negative_eval_threads_means_auto() {
        let mut cfg = ExperimentConfig::quick();
        let doc = "[parallel]\neval_threads = -1\nouter_tasks = -2\n";
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.eval_threads, 0, "negative clamps to auto, not wrap");
        assert_eq!(cfg.outer_tasks, 0, "negative clamps to auto, not wrap");
        assert!(cfg.resolved_eval_threads() >= 1);
    }

    #[test]
    fn auto_eval_threads_respects_budget() {
        let mut cfg = ExperimentConfig::quick();
        cfg.eval_threads = 0;
        let budget = cfg.resolved_eval_threads();
        assert!(budget >= 1);
        // workers × eval threads never exceeds the machine.
        let workers = cfg.ranks * cfg.threads_per_rank;
        assert!(workers * budget <= crate::util::pool::available_threads().max(workers));
    }

    #[test]
    fn bad_labels_rejected() {
        assert!(parse_traversal("sideways").is_err());
        assert!(parse_mode("chaotic").is_err());
        assert!(parse_pipeline("t9").is_err());
        assert!(parse_kmeans_algo("macqueen").is_err());
    }

    #[test]
    fn kmeans_algo_defaults_to_auto_and_overrides_from_toml() {
        let mut cfg = ExperimentConfig::quick();
        assert_eq!(cfg.kmeans_algo, KMeansAlgo::Auto);
        let doc = "[model]\nkmeans_algo = \"elkan\"\n";
        cfg.apply_toml(&parse_toml(doc).unwrap()).unwrap();
        assert_eq!(cfg.kmeans_algo, KMeansAlgo::Elkan);
        assert!(cfg
            .apply_toml(&parse_toml("[model]\nkmeans_algo = \"fast\"\n").unwrap())
            .is_err());
    }

    #[test]
    fn bad_k_range_rejected() {
        let mut cfg = ExperimentConfig::quick();
        let doc = "[search]\nk_min = 20\nk_max = 10\n";
        assert!(cfg.apply_toml(&parse_toml(doc).unwrap()).is_err());
    }
}
