//! Minimal TOML-subset parser (offline `toml` crate stand-in,
//! DESIGN.md §2.3).
//!
//! Supported grammar — everything the `configs/*.toml` experiment files
//! use: `[table.subtable]` headers, `key = value` with string / integer /
//! float / bool / homogeneous scalar arrays, `#` comments, blank lines.
//! Dotted keys in headers create nested tables; duplicate keys are an
//! error (catches config typos early).

use std::collections::BTreeMap;

/// A TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        match self {
            TomlValue::Table(t) => t.get(key),
            _ => None,
        }
    }

    /// Dotted-path lookup: `get_path("search.k_max")`.
    pub fn get_path(&self, path: &str) -> Option<&TomlValue> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML-subset document into a root table.
pub fn parse_toml(text: &str) -> Result<TomlValue, TomlError> {
    let mut root = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let lno = lineno + 1;
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(TomlError {
                    line: lno,
                    msg: "unterminated table header".into(),
                });
            }
            let inner = &line[1..line.len() - 1];
            if inner.is_empty() {
                return Err(TomlError {
                    line: lno,
                    msg: "empty table header".into(),
                });
            }
            current_path = inner.split('.').map(|s| s.trim().to_string()).collect();
            // Materialize the table path.
            ensure_table(&mut root, &current_path, lno)?;
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(TomlError {
                line: lno,
                msg: format!("expected key = value, got '{line}'"),
            });
        };
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim(), lno)?;
        let table = table_at(&mut root, &current_path);
        if table.contains_key(&key) {
            return Err(TomlError {
                line: lno,
                msg: format!("duplicate key '{key}'"),
            });
        }
        table.insert(key, val);
    }
    Ok(TomlValue::Table(root))
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table(
    root: &mut BTreeMap<String, TomlValue>,
    path: &[String],
    line: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        match entry {
            TomlValue::Table(t) => cur = t,
            _ => {
                return Err(TomlError {
                    line,
                    msg: format!("'{part}' is not a table"),
                })
            }
        }
    }
    Ok(())
}

fn table_at<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
) -> &'a mut BTreeMap<String, TomlValue> {
    let mut cur = root;
    for part in path {
        match cur
            .get_mut(part)
            .expect("table path materialized by ensure_table")
        {
            TomlValue::Table(t) => cur = t,
            _ => unreachable!("ensure_table checked"),
        }
    }
    cur
}

fn parse_value(s: &str, line: usize) -> Result<TomlValue, TomlError> {
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err(TomlError {
                line,
                msg: "unterminated string".into(),
            });
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(TomlError {
                line,
                msg: "unterminated array".into(),
            });
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|it| parse_value(it.trim(), line))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(TomlError {
        line,
        msg: format!("cannot parse value '{s}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_experiment_config_shape() {
        let doc = r#"
# experiment config
seed = 42
[search]
k_min = 2
k_max = 30        # inclusive
mode = "vanilla"
select_threshold = 0.75
[parallel]
ranks = 4
threads_per_rank = 2
orders = ["pre", "post"]
enabled = true
"#;
        let t = parse_toml(doc).unwrap();
        assert_eq!(t.get("seed").unwrap().as_int(), Some(42));
        assert_eq!(t.get_path("search.k_max").unwrap().as_int(), Some(30));
        assert_eq!(
            t.get_path("search.mode").unwrap().as_str(),
            Some("vanilla")
        );
        assert_eq!(
            t.get_path("search.select_threshold").unwrap().as_float(),
            Some(0.75)
        );
        assert_eq!(t.get_path("parallel.enabled").unwrap().as_bool(), Some(true));
        let orders = match t.get_path("parallel.orders").unwrap() {
            TomlValue::Array(a) => a.len(),
            _ => 0,
        };
        assert_eq!(orders, 2);
    }

    #[test]
    fn nested_table_headers() {
        let t = parse_toml("[a.b.c]\nx = 1\n").unwrap();
        assert_eq!(t.get_path("a.b.c.x").unwrap().as_int(), Some(1));
    }

    #[test]
    fn duplicate_key_is_error() {
        assert!(parse_toml("x = 1\nx = 2\n").is_err());
    }

    #[test]
    fn bad_syntax_is_error_with_line() {
        let err = parse_toml("ok = 1\nnot a kv\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn int_float_distinction() {
        let t = parse_toml("i = 3\nf = 3.5\n").unwrap();
        assert_eq!(t.get("i").unwrap().as_int(), Some(3));
        assert_eq!(t.get("f").unwrap().as_int(), None);
        assert_eq!(t.get("f").unwrap().as_float(), Some(3.5));
        // Ints coerce to float on demand.
        assert_eq!(t.get("i").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn comments_inside_strings_kept() {
        let t = parse_toml("s = \"a#b\"\n").unwrap();
        assert_eq!(t.get("s").unwrap().as_str(), Some("a#b"));
    }
}
