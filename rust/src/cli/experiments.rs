//! Experiment runners — one per paper table/figure (DESIGN.md §4 index).
//!
//! Each runner prints the same rows/series the paper reports and writes
//! CSV into `results/`. Absolute numbers depend on this testbed; the
//! *shape* (who wins, by what factor, where crossovers fall) is the
//! reproduction target (EXPERIMENTS.md records paper-vs-measured).

use crate::util::error::{ensure, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::{
    binary_bleed_lockstep, binary_bleed_serial, KScorer, Mode, ParallelConfig,
    Pipeline, SearchPolicy, Thresholds, Traversal,
};
use crate::data::{gaussian_blobs, planted_nmf, ScoreProfile};
use crate::metrics::{render_markdown, write_csv, MethodRow, SweepSummary};
use crate::model::{KMeansEvaluator, KMeansScoring, NmfkEvaluator};
use crate::simulate::{simulate_distributed, simulate_parallel_cluster, CostModel};

/// Which model family a single-node experiment drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Nmfk,
    Kmeans,
}

/// E1 — Fig 7: score-vs-k curves with visited/pruned marks, NMFk
/// (silhouette, maximize) and K-means (Davies-Bouldin, minimize).
pub fn fig7(cfg: &ExperimentConfig) -> Result<()> {
    cfg.install_simd();
    println!("== Fig 7: score-vs-k curves (Vanilla & Early-Stop) ==");
    let ks = cfg.ks();
    for (family, k_true) in [(Family::Nmfk, 15u32), (Family::Kmeans, 18u32)] {
        for mode in [Mode::Vanilla, Mode::EarlyStop] {
            let (scorer, policy): (Box<dyn KScorer>, SearchPolicy) =
                build_family(cfg, family, k_true);
            let policy = SearchPolicy { mode, ..policy };
            let r = binary_bleed_serial(&ks, scorer.as_ref(), policy);
            println!(
                "\n{family:?} {} (k_true={k_true}, found={:?}):",
                mode.label(),
                r.k_optimal
            );
            let evaluated = r.log.evaluated();
            let mut rows = Vec::new();
            for &k in &ks {
                let (mark, score) = match r.log.score_of(k) {
                    Some(s) => ("visited", format!("{s:.4}")),
                    None => ("pruned", "-".to_string()),
                };
                println!("  k={k:<3} {mark:<8} {score}");
                rows.push(vec![k.to_string(), mark.to_string(), score]);
            }
            write_csv(
                format!(
                    "{}/fig7_{}_{}.csv",
                    cfg.results_dir,
                    match family {
                        Family::Nmfk => "nmfk",
                        Family::Kmeans => "kmeans",
                    },
                    mode.label()
                ),
                &["k", "mark", "score"],
                &rows,
            )?;
            println!(
                "  visited {}/{} ({:.0}%), order: {evaluated:?}",
                r.log.evaluated_count(),
                ks.len(),
                r.percent_visited()
            );
        }
    }
    Ok(())
}

/// Build the scorer + policy for one family at one k_true.
fn build_family(
    cfg: &ExperimentConfig,
    family: Family,
    k_true: u32,
) -> (Box<dyn KScorer>, SearchPolicy) {
    let mut rng = crate::util::Pcg32::with_stream(cfg.seed, k_true as u64);
    match family {
        Family::Nmfk => {
            // Small planted matrix (quick native path; the HLO path is
            // exercised by examples/end_to_end.rs at manifest shapes).
            // Rows scale with k_true so every planted component keeps a
            // >= 12-row support band (the 1000x1100 paper matrices give
            // ~36 rows per component at k_true = 30).
            let m = (12 * k_true as usize).max(96);
            let n = m + m / 10;
            let ds = planted_nmf(&mut rng, m, n, k_true as usize, 0.01);
            let ev = NmfkEvaluator::native(ds.x, cfg.k_max as usize + 2, cfg.seed)
                .with_perturbations(cfg.perturbations)
                .with_bursts(4)
                .with_eval_threads_for(cfg.resolved_eval_threads(), cfg.engine_workers())
                .with_outer_tasks(cfg.outer_tasks);
            (
                Box::new(ev),
                // stop = 0.0: only true stability collapse (negative
                // silhouette) trips Early-Stop; underfit ranks can dip
                // low-but-positive (§III-C domain caveat).
                SearchPolicy::maximize(
                    Mode::Vanilla,
                    Thresholds {
                        select: cfg.thresholds.select,
                        stop: 0.0,
                    },
                ),
            )
        }
        Family::Kmeans => {
            let ds = gaussian_blobs(&mut rng, 20, k_true as usize, 8, 9.0, 0.5);
            let ev = KMeansEvaluator::native(
                ds.x,
                cfg.k_max as usize + 2,
                KMeansScoring::DaviesBouldin,
                cfg.seed,
            )
            .with_restarts(cfg.restarts)
            .with_eval_threads_for(cfg.resolved_eval_threads(), cfg.engine_workers())
            .with_outer_tasks(cfg.outer_tasks);
            (
                Box::new(ev),
                // Davies-Bouldin minimizes; §IV-A thresholds.
                SearchPolicy::minimize(
                    Mode::Vanilla,
                    Thresholds {
                        select: 0.45,
                        stop: 0.9,
                    },
                ),
            )
        }
    }
}

/// E2 — Fig 8: k-visits vs k_true for {Vanilla, Early-Stop} × {Pre, Post}
/// vs Standard, for NMFk and K-means; prints the paper's mean-%-visited
/// and RMSE summary lines.
pub fn fig8(cfg: &ExperimentConfig, family: Family) -> Result<SweepSummary> {
    cfg.install_simd();
    let label = match family {
        Family::Nmfk => "nmfk",
        Family::Kmeans => "kmeans",
    };
    println!("== Fig 8 ({label}): visits vs k_true ==");
    let ks = cfg.ks();
    let mut sweep = SweepSummary::default();
    let mut csv_rows = Vec::new();
    let k_trues: Vec<u32> = (cfg.k_min..=cfg.k_max)
        .step_by(cfg.sweep_stride)
        .collect();

    for &k_true in &k_trues {
        let (scorer, base_policy) = build_family(cfg, family, k_true);
        // Standard baseline (order-independent).
        let std_r = binary_bleed_serial(
            &ks,
            scorer.as_ref(),
            SearchPolicy {
                mode: Mode::Standard,
                ..base_policy
            },
        );
        sweep.push(MethodRow::from_result(
            "standard",
            "in-order",
            Some(k_true),
            &std_r,
        ));
        csv_rows.push(vec![
            k_true.to_string(),
            "standard".into(),
            "in-order".into(),
            std_r.log.evaluated_count().to_string(),
            fmt_opt(std_r.k_optimal),
        ]);
        for mode in [Mode::Vanilla, Mode::EarlyStop] {
            for order in [Traversal::PreOrder, Traversal::PostOrder] {
                let pcfg = ParallelConfig {
                    ranks: cfg.ranks,
                    threads_per_rank: cfg.threads_per_rank,
                    traversal: order,
                    pipeline: cfg.pipeline,
                };
                let r = binary_bleed_lockstep(
                    &ks,
                    scorer.as_ref(),
                    SearchPolicy {
                        mode,
                        ..base_policy
                    },
                    pcfg,
                );
                sweep.push(MethodRow::from_result(
                    mode.label(),
                    order.label(),
                    Some(k_true),
                    &r,
                ));
                csv_rows.push(vec![
                    k_true.to_string(),
                    mode.label().into(),
                    order.label().into(),
                    r.log.evaluated_count().to_string(),
                    fmt_opt(r.k_optimal),
                ]);
            }
        }
        println!("  k_true={k_true} done");
    }

    write_csv(
        format!("{}/fig8_{label}.csv", cfg.results_dir),
        &["k_true", "method", "order", "visits", "k_found"],
        &csv_rows,
    )?;

    // The paper's summary block (§IV-A percentages + RMSE).
    println!("\nmean % of K visited ({label}):");
    let mut md = Vec::new();
    for (m, o) in [
        ("vanilla", "pre-order"),
        ("vanilla", "post-order"),
        ("early-stop", "pre-order"),
        ("early-stop", "post-order"),
        ("standard", "in-order"),
    ] {
        let pct = sweep.mean_percent_visited(m, o);
        let rmse = sweep.k_rmse(m, o);
        let acc = sweep.accuracy(m, o);
        println!("  {m:<11} {o:<11} {pct:6.1}%   rmse={rmse:.2}  acc={acc:.2}");
        md.push(vec![
            m.into(),
            o.into(),
            format!("{pct:.1}"),
            format!("{rmse:.2}"),
            format!("{acc:.2}"),
        ]);
    }
    std::fs::create_dir_all(&cfg.results_dir)?;
    std::fs::write(
        format!("{}/fig8_{label}_summary.md", cfg.results_dir),
        render_markdown(&["method", "order", "pct_visited", "rmse", "accuracy"], &md),
    )?;
    Ok(sweep)
}

/// E4 — Fig 9 + §IV-C: distributed NMF / RESCAL cost-model simulation.
pub fn fig9(cfg: &ExperimentConfig) -> Result<()> {
    cfg.install_simd();
    println!("== Fig 9: distributed NMF & RESCAL (cost-model simulation) ==");
    let mut rows = Vec::new();
    for (name, ks, cost) in [
        (
            "dNMF",
            (2u32..=8).collect::<Vec<_>>(),
            CostModel::paper_dnmf(),
        ),
        (
            "dRESCAL",
            (2u32..=11).collect::<Vec<_>>(),
            CostModel::paper_drescal(),
        ),
    ] {
        // §IV-C: the stop thresholds were crossed on the last k, so the
        // profile is high through K (k_true = k_max).
        let profile = ScoreProfile::SquareWave {
            k_true: *ks.last().unwrap(),
            high: 0.9,
            low: 0.1,
        };
        let std_out = simulate_distributed(
            &ks,
            &profile,
            SearchPolicy::maximize(Mode::Standard, cfg.thresholds),
            &cost,
        );
        println!(
            "  {name:<8} standard   : {:5.1}% visited, {:7.2} min",
            std_out.percent_visited(),
            std_out.runtime_minutes
        );
        rows.push(vec![
            name.into(),
            "standard".into(),
            "in-order".into(),
            format!("{:.1}", std_out.percent_visited()),
            format!("{:.2}", std_out.runtime_minutes),
        ]);
        for order in [Traversal::PreOrder, Traversal::PostOrder] {
            // Serial distributed regime: the traversal shapes the serial
            // visit order via the recursion (pre) or sorted list (post).
            let out = match order {
                Traversal::PreOrder => simulate_distributed(
                    &ks,
                    &profile,
                    SearchPolicy::maximize(Mode::Vanilla, cfg.thresholds),
                    &cost,
                ),
                _ => {
                    // Post-order: consume the post-order sorted list on one
                    // resource via the lockstep executor, then cost it.
                    let r = binary_bleed_lockstep(
                        &ks,
                        &profile,
                        SearchPolicy::maximize(Mode::Vanilla, cfg.thresholds),
                        ParallelConfig {
                            ranks: 1,
                            threads_per_rank: 1,
                            traversal: Traversal::PostOrder,
                            pipeline: Pipeline::SkipModThenSort,
                        },
                    );
                    let minutes = r.log.evaluated_count() as f64 * cost.minutes(2);
                    crate::simulate::SimOutcome {
                        k_optimal: r.k_optimal,
                        evaluated: r.log.evaluated_count(),
                        total_k: ks.len(),
                        runtime_minutes: minutes,
                        trace: Vec::new(),
                    }
                }
            };
            println!(
                "  {name:<8} vanilla/{:<4}: {:5.1}% visited, {:7.2} min (k*={:?})",
                order.label(),
                out.percent_visited(),
                out.runtime_minutes,
                out.k_optimal
            );
            rows.push(vec![
                name.into(),
                "vanilla".into(),
                order.label().into(),
                format!("{:.1}", out.percent_visited()),
                format!("{:.2}", out.runtime_minutes),
            ]);
        }
    }
    write_csv(
        format!("{}/fig9.csv", cfg.results_dir),
        &["system", "method", "order", "pct_visited", "runtime_min"],
        &rows,
    )?;
    println!(
        "\npaper: dNMF pre 43%/51.43min post 86%/102.86min std 120min;\n       \
         dRESCAL pre 30%/54min post 80%/144min std 180min"
    );
    Ok(())
}

/// E5 — Table II: the four chunk/sort composition orders.
pub fn table2(cfg: &ExperimentConfig) -> Result<()> {
    cfg.install_simd();
    println!("== Table II: chunk/sort compositions, k=[1..11], 2 resources ==");
    let ks: Vec<u32> = (1..=11).collect();
    let mut rows = Vec::new();
    for pipeline in Pipeline::ALL {
        println!("{}", pipeline.label());
        for order in [Traversal::InOrder, Traversal::PreOrder, Traversal::PostOrder] {
            let chunks = pipeline.split(&ks, 2, order);
            let rendered: Vec<String> = chunks
                .iter()
                .map(|c| {
                    format!(
                        "[{}]",
                        c.iter()
                            .map(u32::to_string)
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                })
                .collect();
            println!("  {:<10} {}", order.label(), rendered.join(" "));
            rows.push(vec![
                pipeline.label().into(),
                order.label().into(),
                rendered.join(" "),
            ]);
        }
    }
    write_csv(
        format!("{}/table2.csv", cfg.results_dir),
        &["pipeline", "order", "chunks"],
        &rows,
    )?;
    Ok(())
}

/// E3 — §IV-B multi-node arXiv replay: K={2..100}, 10 ranks × 4 threads,
/// Early-Stop vs Standard, k* = 71.
pub fn arxiv(cfg: &ExperimentConfig) -> Result<()> {
    cfg.install_simd();
    println!("== §IV-B multi-node (arXiv-like replay): K={{2..100}}, k*=71 ==");
    let ks: Vec<u32> = (2..=100).collect();
    // Replay profile: silhouette square wave with k*=71 plus the gradual
    // stop-threshold collapse the paper's Early Stop exploited.
    let profile = ScoreProfile::NoisySquare {
        k_true: 71,
        high: 0.85,
        low: 0.1,
        amp: 0.04,
        seed: cfg.seed,
    };
    let pcfg = ParallelConfig {
        ranks: 10,
        threads_per_rank: 4,
        traversal: Traversal::PreOrder,
        pipeline: Pipeline::SkipModThenSort,
    };
    let mut rows = Vec::new();
    for mode in [Mode::Standard, Mode::EarlyStop] {
        let out = simulate_parallel_cluster(
            &ks,
            &profile,
            SearchPolicy::maximize(mode, cfg.thresholds),
            &CostModel::unit(),
            pcfg,
        );
        println!(
            "  {:<11}: {:5.1}% of K visited, k* = {:?}, makespan {:.1} units",
            mode.label(),
            out.percent_visited(),
            out.k_optimal,
            out.runtime_minutes
        );
        rows.push(vec![
            mode.label().into(),
            format!("{:.1}", out.percent_visited()),
            fmt_opt(out.k_optimal),
            format!("{:.1}", out.runtime_minutes),
        ]);
    }
    write_csv(
        format!("{}/arxiv_multinode.csv", cfg.results_dir),
        &["method", "pct_visited", "k_found", "makespan"],
        &rows,
    )?;
    println!("paper: Early Stop visited 60% of K; both agreed k*=71");
    Ok(())
}

/// E7 — Fig 4 walkthrough: crossings at {7, 8, 10, 24} ⇒ k*=24.
pub fn fig4(cfg: &ExperimentConfig) -> Result<()> {
    cfg.install_simd();
    println!("== Fig 4 walkthrough: selection crossings {{7,8,10,24}} ==");
    let ks: Vec<u32> = (2..=30).collect();
    let profile = ScoreProfile::fig4();
    let r = binary_bleed_serial(
        &ks,
        &profile,
        SearchPolicy::maximize(Mode::Vanilla, cfg.thresholds),
    );
    println!("  visit order: {:?}", r.log.evaluated());
    println!("  pruned     : {:?}", r.log.pruned());
    println!(
        "  k* = {:?} (paper: 24), visited {:.0}%",
        r.k_optimal,
        r.percent_visited()
    );
    ensure!(r.k_optimal == Some(24), "Fig 4 must select 24");
    Ok(())
}

/// E8 — Figs 2/3/5/6 operation dynamics: lockstep trace on k=[1..11].
pub fn dynamics(cfg: &ExperimentConfig) -> Result<()> {
    // Profile scorers only (no native kernels today), but every runner
    // installs the policy on entry so the convention has no exceptions.
    cfg.install_simd();
    println!("== Figs 2/3/5/6 dynamics: k=[1..11] ==");
    // Fig 2/3: 3 resources, Vanilla, k*=7 selected, 6/8 reject.
    let ks: Vec<u32> = (1..=11).collect();
    let vanilla = ScoreProfile::Table {
        scores: vec![(7, 0.9)],
        default: 0.3,
    };
    let cfg3 = ParallelConfig {
        ranks: 3,
        threads_per_rank: 1,
        traversal: Traversal::PreOrder,
        pipeline: Pipeline::SkipModThenSort,
    };
    let r = binary_bleed_lockstep(
        &ks,
        &vanilla,
        SearchPolicy::maximize(
            Mode::Vanilla,
            Thresholds {
                select: 0.75,
                stop: 0.2,
            },
        ),
        cfg3,
    );
    println!("Vanilla, 3 resources, k*=7:");
    print_timeline(&r.log);
    println!("  k* = {:?} (Fig 3: 7)", r.k_optimal);

    // Fig 5/6: 4 resources, Early-Stop, k*=5 selects, k=8 stops.
    let es = ScoreProfile::Table {
        scores: vec![(5, 0.9), (8, 0.1), (9, 0.1), (10, 0.1), (11, 0.1)],
        default: 0.4,
    };
    let cfg4 = ParallelConfig {
        ranks: 4,
        threads_per_rank: 1,
        traversal: Traversal::PreOrder,
        pipeline: Pipeline::SkipModThenSort,
    };
    let r = binary_bleed_lockstep(
        &ks,
        &es,
        SearchPolicy::maximize(
            Mode::EarlyStop,
            Thresholds {
                select: 0.75,
                stop: 0.2,
            },
        ),
        cfg4,
    );
    println!("Early-Stop, 4 resources, k*=5, stop at 8:");
    print_timeline(&r.log);
    println!("  k* = {:?} (Fig 6: 5)", r.k_optimal);
    Ok(())
}

fn print_timeline(log: &crate::coordinator::VisitLog) {
    let mut visits: Vec<_> = log.visits.iter().collect();
    visits.sort_by_key(|v| v.seq);
    for v in visits {
        match v.decision {
            crate::coordinator::Decision::PrunedSkip => {
                println!("    [r{}] k={:<3} pruned", v.rank, v.k)
            }
            d => println!(
                "    [r{}] k={:<3} score={:.2} {:?}",
                v.rank, v.k, v.score, d
            ),
        }
    }
}

fn fmt_opt(k: Option<u32>) -> String {
    k.map(|v| v.to_string()).unwrap_or_else(|| "-".into())
}

/// Run everything (the `bleed experiment all` path).
pub fn all(cfg: &ExperimentConfig) -> Result<()> {
    table2(cfg)?;
    fig4(cfg)?;
    dynamics(cfg)?;
    fig9(cfg)?;
    arxiv(cfg)?;
    fig7(cfg)?;
    fig8(cfg, Family::Nmfk)?;
    fig8(cfg, Family::Kmeans)?;
    Ok(())
}
