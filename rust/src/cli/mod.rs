//! Hand-rolled CLI (offline clap stand-in, DESIGN.md §2.3).
//!
//! ```text
//! bleed search     --model nmfk|kmeans|profile --k-min 2 --k-max 30
//!                  [--mode vanilla|early-stop|standard] [--order pre|post|in]
//!                  [--ranks N | --ranks host1:p1,host2:p2] [--threads T]
//!                  [--eval-threads E]
//!                  [--outer-tasks O] [--simd auto|scalar|vector]
//!                  [--kmeans-algo lloyd|hamerly|elkan|yinyang|auto]
//!                  [--backend hlo|native]
//!                  [--checkpoint FILE] [--resume]
//!                  [--k-true K] [--seed S] [--config FILE]
//! bleed worker     --rank R --ranks host1:p1,host2:p2 [--listen ADDR]
//!                  [--out FILE] [search flags]
//! bleed experiment fig7|fig8|fig9|table2|arxiv|fig4|dynamics|all
//!                  [--preset quick|paper] [--config FILE]
//! bleed artifacts-check [--dir artifacts]
//! ```
//!
//! A `--ranks` value with host:port entries turns `bleed search` into a
//! cluster orchestrator (DESIGN.md §3.7): it self-spawns one `bleed
//! worker` OS process per rank, each running its slots of the shared
//! deterministic work plan over a [`TcpNet`](crate::coordinator::TcpNet)
//! mesh, then merges the per-rank reports. Same seeds ⇒ same k*, visit
//! set, and bitwise-identical per-k records as the in-process run.

pub mod experiments;

use std::collections::HashMap;

#[cfg(feature = "pjrt")]
use crate::util::error::Context;
use crate::util::error::{anyhow, bail, ensure, Result};

use crate::config::{parse_mode, parse_traversal, ExperimentConfig};
use crate::coordinator::{
    EvalOutcome, Evaluation, Fingerprint, KEvaluator, Mode, ParallelConfig, SearchPolicy,
    SearchSession, Thresholds, Traversal,
};
use crate::data::{gaussian_blobs, planted_nmf, ScoreProfile};
use crate::model::{Backend, KMeansEvaluator, KMeansScoring, NmfkEvaluator};

/// Parsed command line: positional words + `--flag value` pairs.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse raw args (everything after the binary name). `--flag` with
    /// no following value (or followed by another flag) is stored as "true".
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                let has_value = i + 1 < raw.len() && !raw[i + 1].starts_with("--");
                if has_value {
                    out.flags.insert(name.to_string(), raw[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.insert(name.to_string(), "true".into());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow!("bad value for --{name}: '{v}'")),
        }
    }
}

const USAGE: &str = "\
bleed — Binary Bleed automatic model selection (paper reproduction)

USAGE:
  bleed search --model nmfk|kmeans|profile [flags]
  bleed gen --out data.bbm [--k-true K] [--per-cluster N] [--d D]
            [--tile-rows T] [--seed S]
  bleed worker --rank R --ranks host1:p1,host2:p2 [--listen ADDR] [--out FILE] [flags]
  bleed experiment fig7|fig8|fig9|table2|arxiv|fig4|dynamics|all [flags]
  bleed artifacts-check [--dir artifacts]
  bleed help

SEARCH FLAGS:
  --k-min N --k-max N      search space (default 2..30)
  --mode M                 standard|vanilla|early-stop (default vanilla)
  --order O                pre|post|in (default pre)
  --ranks N --threads T    parallel shape (default 1x1 = serial); when
                           --ranks is a host:port,host:port,... list the
                           search runs as a multi-process cluster: one
                           `bleed worker` process is self-spawned per
                           rank, gossiping bounds/claims over TCP
                           (port 0 entries get fresh loopback ports)
  --heartbeat-ms MS        cluster heartbeat: each beat renews held claim
                           leases and redials dead links (default 25;
                           0 disables — dead processes then never expire)
  --eval-threads E         intra-evaluation kernel threads per model fit
                           (default 0 = auto: hardware / (ranks*threads))
  --outer-tasks O          concurrent perturbations/restarts per evaluation,
                           split from the eval-thread budget so outer x inner
                           never oversubscribes (default 0 = auto; 1 = off)
  --simd P                 kernel dispatch: auto|scalar|vector (default auto;
                           scalar is the pre-SIMD oracle path — NUMERICS.md)
  --kmeans-algo A          k-means assignment: lloyd|hamerly|elkan|yinyang|auto
                           (default auto = per-shape pick; lloyd is the
                           bitwise oracle — bound paths match it up to
                           documented near-ties, NUMERICS.md)
  --backend B              hlo|native (default native; hlo needs artifacts)
  --checkpoint FILE        journal completed evaluations to FILE as they
                           finish; the pruning-state snapshot + visit log
                           land there at shutdown
  --resume                 warm-start from --checkpoint: already-fitted k
                           are served from their records with zero re-fits
                           (missing file = fresh run; checkpointed failed
                           k are quarantined, never retry-looped)
  --max-attempts N         fit attempts per k before the k is quarantined
                           and the search routes around it (default 1 =
                           no containment: a failing fit crashes the run);
                           retries back off deterministically, jittered
                           from --seed
  --retry-backoff-ms MS    nominal delay before the 2nd attempt, doubling
                           per further attempt (default 10)
  --lease-ttl T            claim-lease TTL in lease-clock ticks: a worker
                           that dies mid-fit stops renewing, survivors
                           re-admit its k after T ticks (default 0 =
                           permanent claims)
  --data FILE.bbm          search an out-of-core tiled dataset instead of
                           generating one in memory (kmeans + native only;
                           write the file with `bleed gen`). Scores are
                           bitwise identical to the in-memory run on the
                           same data; records gain io_bytes/stalls columns
  --prefetch-tiles N       out-of-core prefetch window: tiles read ahead
                           of compute (default 2; 0 = synchronous reads;
                           any depth gives bitwise-identical results)
  --k-true K               planted k for the synthetic dataset (default 15)
  --select X --stop X      thresholds (default 0.75 / 0.2)
  --seed S                 rng seed
  --config FILE            TOML defaults for seed, the parallel.*
                           evaluation knobs (eval_threads, outer_tasks,
                           simd), session.* (checkpoint, resume),
                           cluster.* (ranks, heartbeat_ms) and data.*
                           (path, prefetch_tiles); explicit flags win
GEN FLAGS (write a synthetic dataset as a tiled .bbm file):
  --out FILE.bbm           output path (required)
  --k-true K               planted cluster count (default 15)
  --per-cluster N          rows per cluster (default 25; total rows = K*N)
  --d D                    feature dimensions (default 8)
  --tile-rows T            rows per tile (default 256)
  --seed S                 rng seed (default matches `bleed search`, so
                           gen + search --data reproduces the in-memory
                           kmeans search bitwise)
WORKER FLAGS (one rank process of a cluster search; plus search flags):
  --rank R                 this process's rank in the --ranks list
  --listen ADDR            listen address override (default: the rank's
                           entry in --ranks)
  --out FILE               write the rank report JSON here (default:
                           print to stdout)
EXPERIMENT FLAGS:
  --preset P               quick|paper (default quick)
  --config FILE            TOML overrides (configs/*.toml)
  --simd P                 kernel dispatch override: auto|scalar|vector
";

/// Entry point for the `bleed` binary.
pub fn run(raw_args: &[String]) -> Result<()> {
    let args = Args::parse(raw_args)?;
    match args.positional.first().map(String::as_str) {
        Some("search") => cmd_search(&args),
        Some("gen") => cmd_gen(&args),
        Some("worker") => cmd_worker(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("artifacts-check") => cmd_artifacts_check(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.flag("config") {
        ExperimentConfig::from_file(path)?
    } else {
        ExperimentConfig::by_name(&args.flag_or("preset", "quick"))?
    };
    if let Some(seed) = args.flag_parse::<u64>("seed")? {
        cfg.seed = seed;
    }
    if let Some(simd) = args.flag("simd") {
        cfg.simd = crate::config::parse_simd(simd)?;
    }
    // No install_simd() here: every experiment runner installs the
    // policy itself on entry (they are public entry points also called
    // directly by library users and the smoke tests), so the single
    // per-entry-point convention holds on every path.
    Ok(cfg)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let cfg = experiment_config(args)?;
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    match which {
        "fig7" => experiments::fig7(&cfg),
        "fig8" => {
            experiments::fig8(&cfg, experiments::Family::Nmfk)?;
            experiments::fig8(&cfg, experiments::Family::Kmeans)?;
            Ok(())
        }
        "fig9" => experiments::fig9(&cfg),
        "table2" => experiments::table2(&cfg),
        "arxiv" => experiments::arxiv(&cfg),
        "fig4" => experiments::fig4(&cfg),
        "dynamics" => experiments::dynamics(&cfg),
        "all" => experiments::all(&cfg),
        other => bail!("unknown experiment '{other}'"),
    }
}

/// Every `bleed search` knob, resolved from flags with `--config` TOML
/// fallbacks. `bleed worker` parses the same spec — the orchestrator
/// forwards its resolved values verbatim ([`forward_flags`]), so a
/// worker's evaluator is built from the same inputs as an in-process
/// run's (the determinism contract hangs on this).
#[derive(Debug, Clone)]
struct SearchSpec {
    model: String,
    k_min: u32,
    k_max: u32,
    k_true: u32,
    seed: u64,
    /// In-process rank count; 1 when `cluster` is non-empty.
    ranks: usize,
    threads: usize,
    /// Raw budget: 0 = auto (resolved per consumer via
    /// [`SearchSpec::resolved_eval_threads`], since the engine worker
    /// count differs between in-process and cluster runs).
    eval_threads: usize,
    outer_tasks: usize,
    simd: crate::util::SimdPolicy,
    kmeans_algo: crate::linalg::KMeansAlgo,
    mode: Mode,
    order: Traversal,
    select: f64,
    stop: f64,
    backend: Backend,
    checkpoint: Option<String>,
    resume: bool,
    max_attempts: u32,
    retry_backoff_ms: u64,
    lease_ttl: u64,
    /// Cluster rank listen addresses; empty = in-process run.
    cluster: Vec<String>,
    heartbeat_ms: u64,
    /// Out-of-core dataset path (`.bbm`); None = in-memory synthetic.
    data: Option<String>,
    /// Prefetch window for the out-of-core reader (tiles in flight).
    prefetch_tiles: usize,
}

impl SearchSpec {
    fn ks(&self) -> Vec<u32> {
        (self.k_min..=self.k_max).collect()
    }

    /// Intra-evaluation thread budget (§3.2): explicit, or hardware
    /// threads divided by the engine worker count.
    fn resolved_eval_threads(&self, engine_workers: usize) -> usize {
        match self.eval_threads {
            0 => crate::util::pool::eval_thread_budget(
                crate::util::pool::available_threads(),
                engine_workers,
            ),
            n => n,
        }
    }

    fn fault_policy(&self) -> Option<crate::coordinator::FaultPolicy> {
        if self.max_attempts <= 1 && self.lease_ttl == 0 {
            return None;
        }
        let retry = (self.max_attempts > 1).then(|| crate::coordinator::RetryPolicy {
            max_attempts: self.max_attempts,
            base_backoff: std::time::Duration::from_millis(self.retry_backoff_ms),
            max_backoff: std::time::Duration::from_millis(
                self.retry_backoff_ms.saturating_mul(25),
            ),
            seed: self.seed,
        });
        Some(crate::coordinator::FaultPolicy {
            retry,
            lease_ttl: self.lease_ttl,
        })
    }
}

fn parse_search_spec(args: &Args) -> Result<SearchSpec> {
    // `--config FILE` supplies defaults for the evaluation knobs
    // (seed, parallel.eval_threads / outer_tasks / simd) and the
    // cluster shape; explicit flags always win.
    let file_cfg = match args.flag("config") {
        Some(path) => Some(ExperimentConfig::from_file(path)?),
        None => None,
    };
    let k_min: u32 = args.flag_parse("k-min")?.unwrap_or(2);
    let k_max: u32 = args.flag_parse("k-max")?.unwrap_or(30);
    let k_true: u32 = args.flag_parse("k-true")?.unwrap_or(15);
    let seed: u64 = args
        .flag_parse("seed")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(0xB1EED, |c| c.seed));
    // `--ranks` is overloaded: a bare count keeps the run in-process,
    // a host:port list makes it a multi-process cluster (checked on
    // the raw string — the numeric parse would reject host lists).
    let mut ranks: usize = 1;
    let mut cluster: Vec<String> = Vec::new();
    match args.flag("ranks") {
        Some(raw) if !raw.is_empty() && raw.bytes().all(|b| b.is_ascii_digit()) => {
            ranks = raw
                .parse()
                .map_err(|_| anyhow!("bad value for --ranks: '{raw}'"))?;
        }
        Some(raw) => {
            cluster = raw
                .split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(str::to_string)
                .collect();
            for addr in &cluster {
                ensure!(
                    addr.contains(':'),
                    "--ranks wants a count or host:port,host:port,... (got '{addr}')"
                );
            }
        }
        None => {
            cluster = file_cfg
                .as_ref()
                .map(|c| c.cluster_ranks.clone())
                .unwrap_or_default();
        }
    }
    let threads: usize = args.flag_parse("threads")?.unwrap_or(1);
    let eval_threads: usize = args
        .flag_parse("eval-threads")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(0, |c| c.eval_threads));
    // Outer task level (§3.2): 0 = auto (fill the eval budget).
    let outer_tasks: usize = args
        .flag_parse("outer-tasks")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(0, |c| c.outer_tasks));
    // SIMD dispatch for every native kernel of this run (NUMERICS.md).
    let simd = match args.flag("simd") {
        Some(s) => crate::config::parse_simd(s)?,
        None => file_cfg.as_ref().map_or(crate::util::SimdPolicy::Auto, |c| c.simd),
    };
    // K-means assignment algorithm for the native backend (ignored by
    // the fused HLO kernel and the non-kmeans evaluators).
    let kmeans_algo = match args.flag("kmeans-algo") {
        Some(s) => crate::config::parse_kmeans_algo(s)?,
        None => file_cfg
            .as_ref()
            .map_or(crate::linalg::KMeansAlgo::Auto, |c| c.kmeans_algo),
    };
    let mode = parse_mode(&args.flag_or("mode", "vanilla"))?;
    let order = parse_traversal(&args.flag_or("order", "pre"))?;
    let select: f64 = args.flag_parse("select")?.unwrap_or(0.75);
    let stop: f64 = args.flag_parse("stop")?.unwrap_or(0.2);
    let backend = match args.flag_or("backend", "native").as_str() {
        "hlo" => Backend::Hlo,
        "native" => Backend::Native,
        other => bail!("unknown backend '{other}'"),
    };
    // Session persistence: explicit flags win over config defaults.
    let checkpoint: Option<String> = args
        .flag("checkpoint")
        .map(str::to_string)
        .or_else(|| file_cfg.as_ref().and_then(|c| c.checkpoint.clone()));
    let resume =
        args.flag("resume").is_some() || file_cfg.as_ref().is_some_and(|c| c.resume);
    // Fault tolerance (DESIGN.md §3.6): explicit flags win over config.
    let max_attempts: u32 = args
        .flag_parse("max-attempts")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(1, |c| c.max_attempts))
        .max(1);
    let retry_backoff_ms: u64 = args
        .flag_parse("retry-backoff-ms")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(10, |c| c.retry_backoff_ms));
    let lease_ttl: u64 = args
        .flag_parse("lease-ttl")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(0, |c| c.lease_ttl));
    let heartbeat_ms: u64 = args
        .flag_parse("heartbeat-ms")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(25, |c| c.heartbeat_ms));
    // Out-of-core dataset (DESIGN.md §3.8): explicit flag wins over
    // TOML `data.path`.
    let data: Option<String> = args
        .flag("data")
        .map(str::to_string)
        .or_else(|| file_cfg.as_ref().and_then(|c| c.data_path.clone()));
    let prefetch_tiles: usize = args
        .flag_parse("prefetch-tiles")?
        .unwrap_or_else(|| file_cfg.as_ref().map_or(2, |c| c.prefetch_tiles));
    ensure!(k_min >= 2 && k_min <= k_max, "need 2 <= k-min <= k-max");
    ensure!(
        !resume || checkpoint.is_some(),
        "--resume needs --checkpoint (or session.checkpoint in the config)"
    );
    Ok(SearchSpec {
        model: args.flag_or("model", "profile"),
        k_min,
        k_max,
        k_true,
        seed,
        ranks,
        threads,
        eval_threads,
        outer_tasks,
        simd,
        kmeans_algo,
        mode,
        order,
        select,
        stop,
        backend,
        checkpoint,
        resume,
        max_attempts,
        retry_backoff_ms,
        lease_ttl,
        cluster,
        heartbeat_ms,
        data,
        prefetch_tiles,
    })
}

/// `bleed gen`: write the synthetic k-means dataset as a tiled `.bbm`
/// file for out-of-core searches. With matching `--k-true`/`--seed`
/// (and default shape flags) the payload is byte-identical to the
/// dataset `bleed search --model kmeans` generates in memory, so
/// `gen` + `search --data` reproduces the in-memory search bitwise.
fn cmd_gen(args: &Args) -> Result<()> {
    let out = args
        .flag("out")
        .ok_or_else(|| anyhow!("gen needs --out FILE.bbm"))?;
    let k_true: u32 = args.flag_parse("k-true")?.unwrap_or(15);
    let per_cluster: usize = args.flag_parse("per-cluster")?.unwrap_or(25);
    let d: usize = args.flag_parse("d")?.unwrap_or(8);
    let tile_rows: usize = args.flag_parse("tile-rows")?.unwrap_or(256);
    let seed: u64 = args.flag_parse("seed")?.unwrap_or(0xB1EED);
    ensure!(k_true >= 1, "--k-true must be >= 1");
    ensure!(per_cluster >= 1 && d >= 1, "--per-cluster and --d must be >= 1");
    ensure!(tile_rows >= 1, "--tile-rows must be >= 1");
    // Same generator call as build_evaluator's in-memory kmeans path.
    let mut rng = crate::util::Pcg32::new(seed);
    let ds = gaussian_blobs(&mut rng, per_cluster, k_true as usize, d, 9.0, 0.5);
    crate::linalg::write_bbm(out, &ds.x, tile_rows)?;
    println!(
        "wrote {out}: {} x {} f32 ({} tiles of {tile_rows} rows, {} bytes, fingerprint {:016x})",
        ds.x.rows,
        ds.x.cols,
        ds.x.rows.div_ceil(tile_rows),
        32 + ds.x.rows * ds.x.cols * 4,
        ds.x.fingerprint64(),
    );
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let spec = parse_search_spec(args)?;
    if !spec.cluster.is_empty() {
        return cluster_search(&spec);
    }
    crate::util::simd::set_simd_policy(spec.simd);
    let engine_workers = spec.ranks.max(1) * spec.threads.max(1);
    let eval_threads = spec.resolved_eval_threads(engine_workers);
    let ks = spec.ks();
    let (evaluator, mut policy) = build_evaluator(
        &spec.model,
        spec.k_true,
        spec.k_max,
        spec.seed,
        spec.backend,
        spec.select,
        spec.stop,
        eval_threads,
        // Pool worker set sized for every concurrent engine submitter
        // (one shared evaluator serves all of them).
        engine_workers,
        spec.outer_tasks,
        spec.kmeans_algo,
        spec.data.as_deref(),
        spec.prefetch_tiles,
    )?;
    policy.mode = spec.mode;

    println!(
        "searching K={{{}..{}}} model={} mode={} order={} \
         ranks={}x{} eval-threads={eval_threads} \
         outer-tasks={} simd={} backend={} kmeans-algo={}",
        spec.k_min,
        spec.k_max,
        spec.model,
        spec.mode.label(),
        spec.order.label(),
        spec.ranks,
        spec.threads,
        spec.outer_tasks,
        spec.simd.label(),
        spec.backend.label(),
        spec.kmeans_algo.label()
    );
    let mut session = SearchSession::new(evaluator.as_ref(), policy).with_parallel(
        ParallelConfig {
            ranks: spec.ranks,
            threads_per_rank: spec.threads,
            traversal: spec.order,
            ..Default::default()
        },
    );
    if let Some(path) = &spec.checkpoint {
        session = session.with_checkpoint(path);
    }
    if let Some(faults) = spec.fault_policy() {
        session = session.with_faults(faults);
    }
    let outcome = if spec.resume {
        session.resume(&ks)?
    } else {
        session.run(&ks)?
    };
    let checkpoint = &spec.checkpoint;
    let max_attempts = spec.max_attempts;
    let result = &outcome.result;
    println!(
        "k* = {:?} (score {:?}) — visited {}/{} ({:.0}%) in {:.2}s",
        result.k_optimal,
        result.score,
        result.log.evaluated_count(),
        ks.len(),
        result.percent_visited(),
        result.elapsed.as_secs_f64()
    );
    println!("visit order: {:?}", result.log.evaluated());
    println!("pruned     : {:?}", result.log.pruned());
    if result.partial {
        println!(
            "failed     : {:?} (quarantined after {max_attempts} attempt(s); \
             partial result over the surviving domain)",
            result.failed_ks
        );
        for err in &outcome.failed {
            println!("             k={}: {} [{} attempt(s)]", err.k, err.reason, err.attempts);
        }
    }
    // Rich evaluators yield secondary metrics / fit diagnostics worth a
    // table; scalar profiles don't.
    if outcome
        .records
        .iter()
        .any(|r| !r.secondary.is_empty() || r.diagnostics.fit_error.is_some())
    {
        print!("\n{}", crate::metrics::records_markdown(&outcome.records));
    }
    println!("{}", crate::metrics::cache_summary(&outcome.stats));
    if let Some(path) = &checkpoint {
        println!("checkpoint : {path}");
    }
    Ok(())
}

/// The search flags every `bleed worker` inherits from the
/// orchestrator: the spec's *resolved* values, so a worker re-parses to
/// the identical spec regardless of which side had config-file
/// fallbacks (the labels all round-trip through the parsers).
fn forward_flags(spec: &SearchSpec) -> Vec<String> {
    let flags = [
        ("--model", spec.model.clone()),
        ("--k-min", spec.k_min.to_string()),
        ("--k-max", spec.k_max.to_string()),
        ("--k-true", spec.k_true.to_string()),
        ("--seed", spec.seed.to_string()),
        ("--threads", spec.threads.to_string()),
        ("--eval-threads", spec.eval_threads.to_string()),
        ("--outer-tasks", spec.outer_tasks.to_string()),
        ("--simd", spec.simd.label().to_string()),
        ("--kmeans-algo", spec.kmeans_algo.label().to_string()),
        ("--mode", spec.mode.label().to_string()),
        ("--order", spec.order.label().to_string()),
        ("--select", spec.select.to_string()),
        ("--stop", spec.stop.to_string()),
        ("--backend", spec.backend.label().to_string()),
        ("--max-attempts", spec.max_attempts.to_string()),
        ("--retry-backoff-ms", spec.retry_backoff_ms.to_string()),
        ("--lease-ttl", spec.lease_ttl.to_string()),
        ("--heartbeat-ms", spec.heartbeat_ms.to_string()),
        ("--prefetch-tiles", spec.prefetch_tiles.to_string()),
    ];
    let mut out: Vec<String> = flags
        .into_iter()
        .flat_map(|(name, value)| [name.to_string(), value])
        .collect();
    if let Some(data) = &spec.data {
        out.push("--data".to_string());
        out.push(data.clone());
    }
    out
}

/// Orchestrate a multi-process search (DESIGN.md §3.7): self-spawn one
/// `bleed worker` per `--ranks` entry, wait, merge.
fn cluster_search(spec: &SearchSpec) -> Result<()> {
    ensure!(spec.cluster.len() >= 2, "a cluster needs at least 2 ranks");
    ensure!(
        spec.checkpoint.is_none() && !spec.resume,
        "cluster runs journal per-rank internally; drop --checkpoint/--resume"
    );
    let ks = spec.ks();
    println!(
        "searching K={{{}..{}}} model={} mode={} order={} \
         cluster={} ranks x {} threads (tcp, heartbeat {}ms)",
        spec.k_min,
        spec.k_max,
        spec.model,
        spec.mode.label(),
        spec.order.label(),
        spec.cluster.len(),
        spec.threads,
        spec.heartbeat_ms
    );
    let out = crate::runtime::run_cluster(
        &crate::runtime::ClusterSpec {
            addrs: spec.cluster.clone(),
            forward: forward_flags(spec),
            worker_bin: None,
            out_dir: None,
            env_per_rank: Vec::new(),
            // Survivors can only adopt a dead rank's ks when leases
            // expire; without a TTL a death poisons the whole run.
            tolerate_failures: spec.lease_ttl > 0,
        },
        &ks,
    )?;
    println!(
        "k* = {:?} (score {:?}) — visited {}/{} across {} ranks in {:.2}s",
        out.k_optimal,
        out.score,
        out.visited.len(),
        ks.len(),
        out.ranks,
        out.elapsed_secs
    );
    println!("visited    : {:?}", out.visited);
    println!("pruned     : {:?}", out.pruned);
    if !out.failed.is_empty() {
        println!("failed     : {:?}", out.failed);
    }
    if !out.dead_ranks.is_empty() {
        println!(
            "dead ranks : {:?} (their journaled fits were recovered; \
             unfinished ks re-admitted by survivors)",
            out.dead_ranks
        );
    }
    if out
        .records
        .iter()
        .any(|r| !r.secondary.is_empty() || r.diagnostics.fit_error.is_some())
    {
        print!("\n{}", crate::metrics::records_markdown(&out.records));
    }
    Ok(())
}

/// Chaos hook for the killed-process tests: simulated power loss at one
/// k — `abort()` skips unwinding, the final report, and the shutdown
/// checkpoint, exactly like `kill -9` mid-fit.
struct AbortAtK<'a> {
    inner: &'a dyn KEvaluator,
    at: u32,
}

impl KEvaluator for AbortAtK<'_> {
    fn evaluate(&self, k: u32) -> Evaluation {
        if k == self.at {
            std::process::abort();
        }
        self.inner.evaluate(k)
    }

    fn try_evaluate(&self, k: u32) -> EvalOutcome {
        if k == self.at {
            std::process::abort();
        }
        self.inner.try_evaluate(k)
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn fingerprint(&self) -> Fingerprint {
        self.inner.fingerprint()
    }
}

/// One rank process of a cluster search: bind, mesh up over TCP, run
/// this rank's slots of the shared deterministic work plan, report.
fn cmd_worker(args: &Args) -> Result<()> {
    let spec = parse_search_spec(args)?;
    let rank: usize = args
        .flag_parse("rank")?
        .ok_or_else(|| anyhow!("worker needs --rank R"))?;
    ensure!(
        !spec.cluster.is_empty(),
        "worker needs --ranks host1:port,host2:port,..."
    );
    let addrs = crate::runtime::resolve_cluster_addrs(&spec.cluster)?;
    ensure!(
        rank < addrs.len(),
        "--rank {rank} outside the {}-rank cluster",
        addrs.len()
    );
    let listen = args
        .flag("listen")
        .map(str::to_string)
        .unwrap_or_else(|| addrs[rank].clone());
    let out_path: Option<String> = args.flag("out").map(str::to_string);
    crate::util::simd::set_simd_policy(spec.simd);

    // Bind before the (possibly slow) evaluator build so peers dialing
    // this rank land in the listen backlog instead of burning retries.
    let bound = crate::coordinator::TcpNet::bind(&listen)?;
    let ks = spec.ks();
    let engine_workers = addrs.len().max(1) * spec.threads.max(1);
    let (evaluator, mut policy) = build_evaluator(
        &spec.model,
        spec.k_true,
        spec.k_max,
        spec.seed,
        spec.backend,
        spec.select,
        spec.stop,
        spec.resolved_eval_threads(engine_workers),
        spec.threads.max(1),
        spec.outer_tasks,
        spec.kmeans_algo,
        spec.data.as_deref(),
        spec.prefetch_tiles,
    )?;
    policy.mode = spec.mode;
    let chaos_abort: Option<u32> = std::env::var("BB_CHAOS_ABORT_K")
        .ok()
        .and_then(|v| v.parse().ok());
    let wrapped;
    let eval_ref: &dyn KEvaluator = match chaos_abort {
        Some(at) => {
            wrapped = AbortAtK {
                inner: evaluator.as_ref(),
                at,
            };
            &wrapped
        }
        None => evaluator.as_ref(),
    };

    let net = bound.connect(
        rank,
        &addrs,
        crate::coordinator::TcpNetConfig {
            retry: crate::coordinator::RetryPolicy {
                seed: spec.seed,
                ..crate::coordinator::TcpNetConfig::default().retry
            },
            heartbeat: std::time::Duration::from_millis(spec.heartbeat_ms),
        },
    )?;
    let mut session = SearchSession::new(eval_ref, policy).with_parallel(ParallelConfig {
        ranks: addrs.len(),
        threads_per_rank: spec.threads,
        traversal: spec.order,
        ..Default::default()
    });
    if let Some(path) = &spec.checkpoint {
        session = session.with_checkpoint(path);
    }
    if let Some(faults) = spec.fault_policy() {
        session = session.with_faults(faults);
    }
    let outcome = if spec.resume {
        session.resume_rank(&ks, rank, &net)?
    } else {
        session.run_rank(&ks, rank, &net)?
    };
    // Tear the mesh down before reporting: the Drop joins the service
    // threads, so the report is only written once gossip has settled.
    drop(net);
    let report = crate::runtime::RankReport::from_outcome(rank, &outcome);
    match &out_path {
        Some(p) => report.save(std::path::Path::new(p))?,
        None => println!("{}", report.to_json()),
    }
    Ok(())
}

/// Build a record-producing evaluator for `bleed search`. Public so the
/// multi-process integration tests can construct the exact in-process
/// twin of a cluster run's evaluator when checking the determinism
/// contract.
#[allow(clippy::too_many_arguments)]
pub fn build_evaluator(
    model: &str,
    k_true: u32,
    k_max: u32,
    seed: u64,
    backend: Backend,
    select: f64,
    stop: f64,
    eval_threads: usize,
    engine_workers: usize,
    outer_tasks: usize,
    kmeans_algo: crate::linalg::KMeansAlgo,
    data: Option<&str>,
    prefetch_tiles: usize,
) -> Result<(Box<dyn KEvaluator>, SearchPolicy)> {
    let thresholds = Thresholds { select, stop };
    if let Some(path) = data {
        // Out-of-core backing (DESIGN.md §3.8). kmeans/native only for
        // now: NMFk holds perturbed copies of X per trial and the HLO
        // backend materializes the whole literal, so neither gains
        // anything from a streamed source yet.
        ensure!(
            model == "kmeans",
            "--data currently supports --model kmeans (got '{model}')"
        );
        ensure!(
            backend == Backend::Native,
            "--data requires --backend native (the HLO backend \
             materializes the dataset in device memory)"
        );
        let src = crate::linalg::MatrixSource::open(path, prefetch_tiles)?;
        let ev = KMeansEvaluator::native_src(
            src,
            k_max as usize + 2,
            KMeansScoring::DaviesBouldin,
            seed,
        )
        .with_eval_threads_for(eval_threads, engine_workers)
        .with_outer_tasks(outer_tasks)
        .with_algo(kmeans_algo);
        return Ok((
            Box::new(ev),
            SearchPolicy::minimize(
                Mode::Vanilla,
                Thresholds {
                    select: 0.45,
                    stop: 0.9,
                },
            ),
        ));
    }
    let mut rng = crate::util::Pcg32::new(seed);
    match model {
        "profile" => Ok((
            Box::new(ScoreProfile::SquareWave {
                k_true,
                high: 0.9,
                low: 0.1,
            }),
            SearchPolicy::maximize(Mode::Vanilla, thresholds),
        )),
        "nmfk" => {
            let ev: NmfkEvaluator = match backend {
                Backend::Hlo => nmfk_hlo_evaluator(&mut rng, k_true, seed)?,
                Backend::Native => {
                    let ds = planted_nmf(&mut rng, 80, 88, k_true as usize, 0.01);
                    NmfkEvaluator::native(ds.x, k_max as usize + 2, seed)
                }
            }
            .with_eval_threads_for(eval_threads, engine_workers)
            .with_outer_tasks(outer_tasks);
            Ok((
                Box::new(ev),
                SearchPolicy::maximize(Mode::Vanilla, thresholds),
            ))
        }
        "kmeans" => {
            let ev: KMeansEvaluator = match backend {
                Backend::Hlo => kmeans_hlo_evaluator(&mut rng, k_true, seed)?,
                Backend::Native => {
                    let ds =
                        gaussian_blobs(&mut rng, 25, k_true as usize, 8, 9.0, 0.5);
                    KMeansEvaluator::native(
                        ds.x,
                        k_max as usize + 2,
                        KMeansScoring::DaviesBouldin,
                        seed,
                    )
                }
            }
            .with_eval_threads_for(eval_threads, engine_workers)
            .with_outer_tasks(outer_tasks)
            .with_algo(kmeans_algo);
            Ok((
                Box::new(ev),
                SearchPolicy::minimize(
                    Mode::Vanilla,
                    Thresholds {
                        select: 0.45,
                        stop: 0.9,
                    },
                ),
            ))
        }
        other => bail!("unknown model '{other}' (profile|nmfk|kmeans)"),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = args.flag_or("dir", "artifacts");
    let store = crate::runtime::ArtifactStore::open(&dir)
        .with_context(|| format!("opening artifact store at {dir}"))?;
    println!("platform: {}", store.platform());
    let names: Vec<String> = store.manifest().entries.keys().cloned().collect();
    for name in &names {
        let t = std::time::Instant::now();
        store.warm(name)?;
        println!("  {name:<16} compiled in {:.0}ms", t.elapsed().as_secs_f64() * 1e3);
    }
    println!("{} entries OK (preset={})", names.len(), store.manifest().preset);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts_check(_args: &Args) -> Result<()> {
    bail!("artifacts-check requires a build with `--features pjrt`")
}

/// `bleed search --backend hlo` scorers — real under `pjrt`, an
/// actionable error otherwise.
#[cfg(feature = "pjrt")]
fn nmfk_hlo_evaluator(
    rng: &mut crate::util::Pcg32,
    k_true: u32,
    seed: u64,
) -> Result<NmfkEvaluator> {
    let store = std::sync::Arc::new(crate::model::SharedStore::open_default()?);
    let m = store.param("nmf_m")?;
    let n = store.param("nmf_n")?;
    let ds = planted_nmf(rng, m, n, k_true as usize, 0.01);
    NmfkEvaluator::hlo(ds.x, store, seed)
}

#[cfg(not(feature = "pjrt"))]
fn nmfk_hlo_evaluator(
    _rng: &mut crate::util::Pcg32,
    _k_true: u32,
    _seed: u64,
) -> Result<NmfkEvaluator> {
    bail!("--backend hlo requires a build with `--features pjrt`")
}

#[cfg(feature = "pjrt")]
fn kmeans_hlo_evaluator(
    rng: &mut crate::util::Pcg32,
    k_true: u32,
    seed: u64,
) -> Result<KMeansEvaluator> {
    let store = std::sync::Arc::new(crate::model::SharedStore::open_default()?);
    let n = store.param("km_n")?;
    let d = store.param("km_d")?;
    let ds = gaussian_blobs(rng, n / k_true as usize, k_true as usize, d, 9.0, 0.5);
    // Pad to exact n rows if k_true does not divide n.
    let mut x = ds.x;
    while x.rows < n {
        let row: Vec<f32> = x.row(x.rows - 1).to_vec();
        x.data.extend_from_slice(&row);
        x.rows += 1;
    }
    KMeansEvaluator::hlo(x, KMeansScoring::DaviesBouldin, store, seed)
}

#[cfg(not(feature = "pjrt"))]
fn kmeans_hlo_evaluator(
    _rng: &mut crate::util::Pcg32,
    _k_true: u32,
    _seed: u64,
) -> Result<KMeansEvaluator> {
    bail!("--backend hlo requires a build with `--features pjrt`")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = args(&["search", "--k-max", "40", "--verbose", "--mode", "vanilla"]);
        assert_eq!(a.positional, vec!["search"]);
        assert_eq!(a.flag("k-max"), Some("40"));
        assert_eq!(a.flag("verbose"), Some("true"));
        assert_eq!(a.flag_parse::<u32>("k-max").unwrap(), Some(40));
    }

    #[test]
    fn bad_flag_value_errors() {
        let a = args(&["search", "--k-max", "forty"]);
        assert!(a.flag_parse::<u32>("k-max").is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&["frobnicate".to_string()]).is_err());
    }

    #[test]
    fn profile_search_end_to_end() {
        run(&[
            "search".into(),
            "--model".into(),
            "profile".into(),
            "--k-true".into(),
            "17".into(),
        ])
        .unwrap();
    }

    #[test]
    fn checkpointed_search_writes_and_resumes() {
        let path = std::env::temp_dir().join(format!(
            "bb_cli_ckpt_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let base = [
            "search",
            "--model",
            "profile",
            "--k-true",
            "12",
            "--checkpoint",
            path.to_str().unwrap(),
        ];
        run(&base.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap();
        assert!(path.exists(), "checkpoint file written");
        let mut resumed: Vec<String> =
            base.iter().map(|s| s.to_string()).collect();
        resumed.push("--resume".into());
        run(&resumed).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fault_flags_search_end_to_end() {
        // A clean evaluator under full fault tolerance behaves exactly
        // like the plain run (the containment layers are pass-through).
        run(&[
            "search".into(),
            "--model".into(),
            "profile".into(),
            "--k-true".into(),
            "17".into(),
            "--max-attempts".into(),
            "3".into(),
            "--retry-backoff-ms".into(),
            "1".into(),
            "--lease-ttl".into(),
            "8".into(),
            "--ranks".into(),
            "2".into(),
            "--threads".into(),
            "2".into(),
        ])
        .unwrap();
    }

    #[test]
    fn ranks_flag_detects_cluster_lists() {
        // Bare count: in-process, no cluster.
        let spec = parse_search_spec(&args(&["search", "--ranks", "3"])).unwrap();
        assert_eq!(spec.ranks, 3);
        assert!(spec.cluster.is_empty());
        // host:port list: cluster mode (raw-string detection — the
        // numeric parse would have rejected this).
        let spec = parse_search_spec(&args(&[
            "search",
            "--ranks",
            "127.0.0.1:0, 127.0.0.1:0",
        ]))
        .unwrap();
        assert_eq!(spec.cluster, vec!["127.0.0.1:0", "127.0.0.1:0"]);
        assert_eq!(spec.ranks, 1);
        // Neither a count nor host:port entries: typed error.
        assert!(parse_search_spec(&args(&["search", "--ranks", "2x"])).is_err());
    }

    #[test]
    fn cluster_search_rejects_checkpoint_flags() {
        let spec = parse_search_spec(&args(&[
            "search",
            "--ranks",
            "127.0.0.1:0,127.0.0.1:0",
            "--checkpoint",
            "/tmp/never-written.json",
        ]))
        .unwrap();
        assert!(cluster_search(&spec).is_err(), "checkpointing is per-rank");
    }

    #[test]
    fn forward_flags_roundtrip_to_the_same_spec() {
        // The orchestrator→worker contract: re-parsing the forwarded
        // flags yields the identical spec, so both sides build the same
        // evaluator (determinism over the wire).
        let spec = parse_search_spec(&args(&[
            "search",
            "--model",
            "kmeans",
            "--k-min",
            "3",
            "--k-max",
            "17",
            "--k-true",
            "9",
            "--mode",
            "standard",
            "--order",
            "post",
            "--simd",
            "scalar",
            "--kmeans-algo",
            "elkan",
            "--select",
            "0.45",
            "--stop",
            "0.9",
            "--max-attempts",
            "3",
            "--lease-ttl",
            "6",
            "--heartbeat-ms",
            "10",
            "--seed",
            "42",
        ]))
        .unwrap();
        let mut raw = vec!["worker".to_string()];
        raw.extend(forward_flags(&spec));
        let respec = parse_search_spec(&Args::parse(&raw).unwrap()).unwrap();
        assert_eq!(format!("{spec:?}"), format!("{respec:?}"));
    }

    #[test]
    fn worker_without_rank_or_cluster_errors() {
        assert!(run(&["worker".to_string()]).is_err());
        assert!(run(&[
            "worker".into(),
            "--rank".into(),
            "0".into(),
            "--ranks".into(),
            "3".into(),
        ])
        .is_err());
    }

    #[test]
    fn resume_without_checkpoint_errors() {
        assert!(run(&[
            "search".into(),
            "--model".into(),
            "profile".into(),
            "--resume".into(),
        ])
        .is_err());
    }
}
