//! END-TO-END DRIVER — the full-system validation run recorded in
//! EXPERIMENTS.md: all three layers composing on a real small workload.
//!
//! For each of several planted ranks it runs NMFk automatic model
//! selection over the AOT HLO artifacts (L1 Pallas kernels inside the L2
//! jax graph, executed by the L3 Rust coordinator via PJRT), comparing
//! Standard grid search vs Binary Bleed Vanilla vs Early-Stop: recovered
//! k, percent of K visited, wall-clock.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::sync::Arc;

use binary_bleed::coordinator::{
    binary_bleed_serial, Mode, SearchPolicy, Thresholds,
};
use binary_bleed::data::planted_nmf;
use binary_bleed::metrics::{render_markdown, write_csv};
use binary_bleed::model::{NmfkEvaluator, SharedStore};
use binary_bleed::util::{Pcg32, Stopwatch};

fn main() -> binary_bleed::util::error::Result<()> {
    let store = Arc::new(SharedStore::open_default()?);
    let (m, n) = (store.param("nmf_m")?, store.param("nmf_n")?);
    store.warm(&["nmf_run"])?;
    println!("end-to-end: NMFk over {m}x{n} planted matrices, K={{2..14}}");
    println!("layers: L3 rust coordinator -> PJRT -> L2 jax graph -> L1 pallas kernels\n");

    let ks: Vec<u32> = (2..=14).collect();
    // stop = 0.0: only a true stability collapse (negative silhouette)
    // trips Early-Stop — underfit ranks can dip low-but-positive, the
    // domain caveat of §III-C.
    let thresholds = Thresholds {
        select: 0.75,
        stop: 0.0,
    };
    let k_trues = [4u32, 6, 9];
    let mut rows = Vec::new();
    let total = Stopwatch::new();

    for &k_true in &k_trues {
        let mut rng = Pcg32::with_stream(0xE2E, k_true as u64);
        let ds = planted_nmf(&mut rng, m, n, k_true as usize, 0.01);
        for mode in [Mode::Standard, Mode::Vanilla, Mode::EarlyStop] {
            let ev = NmfkEvaluator::hlo(ds.x.clone(), store.clone(), 0xE2E)?
                .with_perturbations(3)
                .with_bursts(3);
            let sw = Stopwatch::new();
            let r = binary_bleed_serial(
                &ks,
                &ev,
                SearchPolicy::maximize(mode, thresholds),
            );
            let secs = sw.elapsed_secs();
            let found = r.k_optimal;
            let ok = found == Some(k_true);
            println!(
                "k_true={k_true} {:<11} -> k*={:<8} visited {:2}/{} ({:3.0}%) {:6.1}s {}",
                mode.label(),
                format!("{found:?}"),
                r.log.evaluated_count(),
                ks.len(),
                r.percent_visited(),
                secs,
                if ok { "OK" } else { "±" }
            );
            rows.push(vec![
                k_true.to_string(),
                mode.label().to_string(),
                found.map(|k| k.to_string()).unwrap_or("-".into()),
                r.log.evaluated_count().to_string(),
                format!("{:.1}", r.percent_visited()),
                format!("{secs:.1}"),
            ]);
        }
    }

    write_csv(
        "results/end_to_end.csv",
        &["k_true", "method", "k_found", "visits", "pct_visited", "seconds"],
        &rows,
    )?;
    println!(
        "\n{}",
        render_markdown(
            &["k_true", "method", "k_found", "visits", "pct", "secs"],
            &rows
        )
    );
    println!("total wall-clock {:.1}s; csv -> results/end_to_end.csv", total.elapsed_secs());

    // The headline claim: pruning methods visit strictly less than the
    // grid while agreeing on k (within the paper's own RMSE tolerance).
    let std_visits: usize = rows
        .iter()
        .filter(|r| r[1] == "standard")
        .map(|r| r[3].parse::<usize>().unwrap())
        .sum();
    let es_visits: usize = rows
        .iter()
        .filter(|r| r[1] == "early-stop")
        .map(|r| r[3].parse::<usize>().unwrap())
        .sum();
    binary_bleed::ensure!(
        es_visits < std_visits,
        "early-stop must prune: {es_visits} !< {std_visits}"
    );
    println!(
        "early-stop visited {es_visits} total k vs standard {std_visits} \
         ({:.0}% of the grid)",
        100.0 * es_visits as f64 / std_visits as f64
    );
    Ok(())
}
