//! K-means cluster-count selection with Davies-Bouldin scoring
//! (minimization task, §IV-A) over the HLO `kmeans_run` +
//! `davies_bouldin` artifacts, searched by parallel Binary Bleed.
//!
//! ```bash
//! make artifacts && cargo run --release --example kmeans_selection
//! ```

use std::sync::Arc;

use binary_bleed::coordinator::{
    binary_bleed_parallel, Mode, ParallelConfig, SearchPolicy, Thresholds,
};
use binary_bleed::data::gaussian_blobs;
use binary_bleed::model::{KMeansEvaluator, KMeansScoring, SharedStore};
use binary_bleed::util::{Pcg32, Stopwatch};

fn main() -> binary_bleed::util::error::Result<()> {
    let store = Arc::new(SharedStore::open_default()?);
    let (n, d) = (store.param("km_n")?, store.param("km_d")?);

    // §IV-A: Gaussian clusters with sigma 0.5.
    let k_true = 8usize; // divides km_n in both presets
    let mut rng = Pcg32::new(7);
    let ds = gaussian_blobs(&mut rng, n / k_true, k_true, d, 9.0, 0.5);
    println!("dataset: {n} points, {d} dims, planted k = {k_true}");

    store.warm(&["kmeans_run", "davies_bouldin"])?;
    let evaluator =
        KMeansEvaluator::hlo(ds.x, KMeansScoring::DaviesBouldin, store, 7)?
            .with_restarts(2);

    // Davies-Bouldin is minimized: select below 0.45, stop above 0.9.
    let policy = SearchPolicy::minimize(
        Mode::Vanilla,
        Thresholds {
            select: 0.45,
            stop: 0.9,
        },
    );

    let ks: Vec<u32> = (2..=30).collect();
    // 2 ranks x 1 thread: few enough workers that pruning broadcasts
    // land while later k are still queued.
    let cfg = ParallelConfig {
        ranks: 2,
        threads_per_rank: 1,
        ..Default::default()
    };
    let sw = Stopwatch::new();
    let result = binary_bleed_parallel(&ks, &evaluator, policy, cfg);
    println!(
        "\n2 ranks x 1 thread, Vanilla, K={{2..30}} in {:.1}s",
        sw.elapsed_secs()
    );
    println!("  k* = {:?} (DB {:?})", result.k_optimal, result.score);
    println!(
        "  visited {}/{} ({:.0}%), pruned {:?}",
        result.log.evaluated_count(),
        ks.len(),
        result.percent_visited(),
        result.log.pruned()
    );
    Ok(())
}
