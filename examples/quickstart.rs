//! Quickstart: Binary Bleed on a synthetic score profile — the 60-second
//! tour of the public API (no artifacts required).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use binary_bleed::coordinator::{
    binary_bleed_parallel, binary_bleed_serial, standard_search, Mode,
    ParallelConfig, SearchPolicy, Thresholds,
};
use binary_bleed::data::ScoreProfile;

fn main() {
    // The search space: K = {2..30}, as in the paper's §IV-A.
    let ks: Vec<u32> = (2..=30).collect();

    // A scorer is anything Fn(u32) -> f64 (or a KScorer impl). Here: the
    // paper's ideal square-wave silhouette with true k = 15.
    let profile = ScoreProfile::SquareWave {
        k_true: 15,
        high: 0.9,
        low: 0.1,
    };

    let policy = SearchPolicy::maximize(
        Mode::Vanilla,
        Thresholds {
            select: 0.75,
            stop: 0.2,
        },
    );

    // 1. The Standard baseline: exhaustive grid search.
    let std_r = standard_search(&ks, &profile, policy);
    println!(
        "standard   : k*={:?}  visited {:2}/{} (100%)",
        std_r.k_optimal,
        std_r.log.evaluated_count(),
        ks.len()
    );

    // 2. Serial Binary Bleed (Alg 1): binary-search order + pruning.
    let bleed_r = binary_bleed_serial(&ks, &profile, policy);
    println!(
        "bleed      : k*={:?}  visited {:2}/{} ({:.0}%)  order {:?}",
        bleed_r.k_optimal,
        bleed_r.log.evaluated_count(),
        ks.len(),
        bleed_r.percent_visited(),
        bleed_r.log.evaluated()
    );

    // 3. Early-Stop: also prunes above once scores collapse.
    let es_policy = SearchPolicy {
        mode: Mode::EarlyStop,
        ..policy
    };
    let es_r = binary_bleed_serial(&ks, &profile, es_policy);
    println!(
        "early-stop : k*={:?}  visited {:2}/{} ({:.0}%)",
        es_r.k_optimal,
        es_r.log.evaluated_count(),
        ks.len(),
        es_r.percent_visited()
    );

    // 4. Multi-rank, multi-thread (Alg 3+4): 3 ranks x 2 threads with
    //    channel broadcasts propagating the pruning bounds.
    let cfg = ParallelConfig {
        ranks: 3,
        threads_per_rank: 2,
        ..Default::default()
    };
    let par_r = binary_bleed_parallel(&ks, &profile, es_policy, cfg);
    println!(
        "3x2 ranks  : k*={:?}  visited {:2}/{} ({:.0}%)",
        par_r.k_optimal,
        par_r.log.evaluated_count(),
        ks.len(),
        par_r.percent_visited()
    );

    assert_eq!(std_r.k_optimal, Some(15));
    assert_eq!(bleed_r.k_optimal, Some(15));
    assert_eq!(par_r.k_optimal, Some(15));
    println!("\nall engines agree: k* = 15, Binary Bleed pruned the rest.");
}
