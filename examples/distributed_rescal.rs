//! §IV-C / Fig 9 — the distributed setting: whole-cluster-per-k RESCAL
//! and NMF with calibrated cost models, plus a live HLO RESCALk
//! mini-factorization proving the same code path runs for real.
//!
//! ```bash
//! make artifacts && cargo run --release --example distributed_rescal
//! ```

use std::sync::Arc;

use binary_bleed::coordinator::{Mode, SearchPolicy, Thresholds};
use binary_bleed::data::{planted_rescal, ScoreProfile};
use binary_bleed::model::{RescalEvaluator, SharedStore};
use binary_bleed::simulate::{simulate_distributed, CostModel};
use binary_bleed::util::Pcg32;

fn main() -> binary_bleed::util::error::Result<()> {
    let policy = SearchPolicy::maximize(
        Mode::Vanilla,
        Thresholds {
            select: 0.75,
            stop: 0.2,
        },
    );

    // ---- Fig 9 simulation: paper-calibrated per-k costs ----
    println!("== Fig 9 (simulated 50TB/11.5TB clusters) ==");
    for (name, ks, cost, paper) in [
        (
            "pyDNMFk  (52k cores)",
            (2u32..=8).collect::<Vec<_>>(),
            CostModel::paper_dnmf(),
            "paper: 43% visited, 51.43 min vs 120",
        ),
        (
            "pyDRESCALk (4096 cores)",
            (2u32..=11).collect::<Vec<_>>(),
            CostModel::paper_drescal(),
            "paper: 30% visited, 54 min vs 180",
        ),
    ] {
        let profile = ScoreProfile::SquareWave {
            k_true: *ks.last().unwrap(),
            high: 0.9,
            low: 0.1,
        };
        let std_out = simulate_distributed(
            &ks,
            &profile,
            SearchPolicy {
                mode: Mode::Standard,
                ..policy
            },
            &cost,
        );
        let out = simulate_distributed(&ks, &profile, policy, &cost);
        println!("{name}:");
        println!(
            "  standard: {:5.1}% visited, {:6.2} min",
            std_out.percent_visited(),
            std_out.runtime_minutes
        );
        println!(
            "  bleed   : {:5.1}% visited, {:6.2} min  (speedup {:.2}x)  [{paper}]",
            out.percent_visited(),
            out.runtime_minutes,
            std_out.runtime_minutes / out.runtime_minutes
        );
        for v in &out.trace {
            println!(
                "    t={:6.1}..{:6.1} min  k={:<3} score={:.2}",
                v.start, v.end, v.k, v.score
            );
        }
    }

    // ---- Live RESCALk through the HLO artifacts ----
    println!("\n== live RESCALk selection (HLO rescal_step artifact) ==");
    let store = Arc::new(SharedStore::open_default()?);
    let (s, n) = (store.param("rescal_s")?, store.param("rescal_n")?);
    let mut rng = Pcg32::new(99);
    let k_true = 3usize;
    let t = planted_rescal(&mut rng, s, n, k_true, 0.01);
    // Multiplicative RESCAL needs more sweeps to sharpen the stability
    // cliff; the select threshold sits under the k_true plateau.
    let ev = RescalEvaluator::hlo(t.slices, store, 99)?.with_bursts(12);
    let ks: Vec<u32> = (2..=8).collect();
    let rescal_policy = SearchPolicy::maximize(
        Mode::Vanilla,
        Thresholds {
            select: 0.65,
            stop: 0.2,
        },
    );
    let r = binary_bleed_serial_wrap(&ks, &ev, rescal_policy);
    println!(
        "  planted k={k_true}, found k*={:?}, visited {}/{}",
        r.k_optimal,
        r.log.evaluated_count(),
        ks.len()
    );
    Ok(())
}

fn binary_bleed_serial_wrap(
    ks: &[u32],
    ev: &dyn binary_bleed::coordinator::KScorer,
    policy: SearchPolicy,
) -> binary_bleed::coordinator::SearchResult {
    binary_bleed::coordinator::binary_bleed_serial(ks, ev, policy)
}
