//! §IV-B — the multi-node setting: NMFk topic-count selection over an
//! arXiv-like corpus on a simulated 10-node × 4-GPU cluster (the paper's
//! Chicoma allocation), K = {2..100}, k* = 71.
//!
//! Two parts: (a) the cluster-schedule replay reporting visited-% as the
//! paper does, and (b) a real (scaled-down) NMFk run over the synthetic
//! corpus proving the corpus generator feeds the actual evaluator.
//!
//! ```bash
//! cargo run --release --example arxiv_multinode
//! ```

use binary_bleed::coordinator::{
    binary_bleed_serial, Mode, ParallelConfig, Pipeline, SearchPolicy,
    Thresholds, Traversal,
};
use binary_bleed::data::{arxiv_like, ScoreProfile};
use binary_bleed::model::NmfkEvaluator;
use binary_bleed::simulate::{simulate_parallel_cluster, CostModel};
use binary_bleed::util::Pcg32;

fn main() {
    let thresholds = Thresholds {
        select: 0.75,
        stop: 0.2,
    };

    // ---- (a) Cluster replay: 10 ranks x 4 workers, K={2..100} ----
    println!("== Chicoma replay: 10 nodes x 4 A100s, K={{2..100}}, k*=71 ==");
    let ks: Vec<u32> = (2..=100).collect();
    let profile = ScoreProfile::NoisySquare {
        k_true: 71,
        high: 0.85,
        low: 0.1,
        amp: 0.04,
        seed: 0xA8C1,
    };
    let cfg = ParallelConfig {
        ranks: 10,
        threads_per_rank: 4,
        traversal: Traversal::PreOrder,
        pipeline: Pipeline::SkipModThenSort,
    };
    for mode in [Mode::Standard, Mode::EarlyStop] {
        let out = simulate_parallel_cluster(
            &ks,
            &profile,
            SearchPolicy::maximize(mode, thresholds),
            &CostModel::unit(),
            cfg,
        );
        println!(
            "  {:<11}: visited {:5.1}% of K, k* = {:?}, makespan {:.1} k-units",
            mode.label(),
            out.percent_visited(),
            out.k_optimal,
            out.runtime_minutes
        );
    }
    println!("  paper: Early Stop visited 60% of K; both selected k*=71");

    // ---- (b) Real NMFk over the synthetic corpus (scaled) ----
    println!("\n== real NMFk over arXiv-like corpus (scaled to 300x160) ==");
    let mut rng = Pcg32::new(0xA8C1);
    let corpus = arxiv_like(&mut rng, 300, 160, 7, 60);
    println!(
        "  corpus: vocab={} docs={} planted topics={}",
        corpus.vocab, corpus.docs, corpus.k_topics
    );
    let ev = NmfkEvaluator::native(corpus.x, 16, 0xA8C1)
        .with_perturbations(3)
        .with_bursts(4);
    let ks_small: Vec<u32> = (2..=14).collect();
    let r = binary_bleed_serial(
        &ks_small,
        &ev,
        SearchPolicy::maximize(Mode::EarlyStop, thresholds),
    );
    println!(
        "  found k* = {:?} (planted 7), visited {}/{} ({:.0}%)",
        r.k_optimal,
        r.log.evaluated_count(),
        ks_small.len(),
        r.percent_visited()
    );
}
