//! NMFk automatic model selection on a planted-rank matrix through the
//! full three-layer stack: Rust coordinator → PJRT → AOT HLO (Pallas
//! NMF-update kernels inside).
//!
//! ```bash
//! make artifacts && cargo run --release --example nmfk_selection
//! ```

use std::sync::Arc;

use binary_bleed::coordinator::{
    binary_bleed_serial, Mode, SearchPolicy, Thresholds,
};
use binary_bleed::data::planted_nmf;
use binary_bleed::model::{NmfkEvaluator, SharedStore};
use binary_bleed::util::{Pcg32, Stopwatch};

fn main() -> binary_bleed::util::error::Result<()> {
    let store = Arc::new(SharedStore::open_default()?);
    let (m, n) = (store.param("nmf_m")?, store.param("nmf_n")?);
    println!("artifact preset: X is {m}x{n} (quick preset; see configs/)");

    // The paper's §IV-A workload: synthetic matrix with predetermined k.
    let k_true = 6usize;
    let mut rng = Pcg32::new(42);
    let ds = planted_nmf(&mut rng, m, n, k_true, 0.01);
    println!("planted rank: {k_true}");

    store.warm(&["nmf_run"])?;
    let evaluator = NmfkEvaluator::hlo(ds.x, store, 42)?
        .with_perturbations(3)
        .with_bursts(3);

    let ks: Vec<u32> = (2..=14).collect();
    let policy = SearchPolicy::maximize(
        Mode::EarlyStop,
        Thresholds {
            select: 0.75,
            stop: 0.2,
        },
    );

    let sw = Stopwatch::new();
    let result = binary_bleed_serial(&ks, &evaluator, policy);
    println!(
        "\nBinary Bleed Early-Stop over K={{2..14}} finished in {:.1}s",
        sw.elapsed_secs()
    );
    println!("  k* = {:?} (score {:?})", result.k_optimal, result.score);
    println!(
        "  visited {}/{} ({:.0}%): {:?}",
        result.log.evaluated_count(),
        ks.len(),
        result.percent_visited(),
        result.log.evaluated()
    );
    println!("  pruned: {:?}", result.log.pruned());
    for &k in result.log.evaluated().iter() {
        println!(
            "    k={k:<3} stability silhouette = {:.3}",
            result.log.score_of(k).unwrap()
        );
    }
    Ok(())
}
