//! `bleedlint` CLI: lint the repo's Rust sources against the invariant
//! catalog in DESIGN.md §3.5 (S24).
//!
//! Usage:
//!   cargo run -p bleedlint              # lint rust/src/** (the default root)
//!   cargo run -p bleedlint -- <dir>...  # lint explicit roots
//!   cargo run -p bleedlint -- --list    # print the lint catalog
//!
//! Exit status: 0 when clean, 1 when any finding (or a root is
//! unreadable), so CI and the tier-1 test can gate on it directly.

use std::path::PathBuf;
use std::process::ExitCode;

use bleedlint::{count_rs_files, lint_tree, ALL_LINTS};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--list") {
        for l in ALL_LINTS {
            println!("{:>2} {:<40} {}", l.code(), l.name(), l.contract());
        }
        return ExitCode::SUCCESS;
    }

    let roots: Vec<PathBuf> = if args.is_empty() {
        // tools/bleedlint/ -> repo root -> rust/src
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        vec![manifest.join("../..").join("rust").join("src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };

    let mut n_findings = 0usize;
    let mut n_files = 0usize;
    for root in &roots {
        match count_rs_files(root) {
            Ok(n) => n_files += n,
            Err(e) => {
                eprintln!("bleedlint: {e}");
                return ExitCode::FAILURE;
            }
        }
        match lint_tree(root) {
            Ok(findings) => {
                for f in &findings {
                    println!("{f}");
                }
                n_findings += findings.len();
            }
            Err(e) => {
                eprintln!("bleedlint: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    if n_findings == 0 {
        eprintln!("bleedlint: {n_files} files clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("bleedlint: {n_findings} finding(s) across {n_files} files");
        ExitCode::FAILURE
    }
}
