//! The `bleedlint` analyzer: a small Rust lexer + line analyzer that
//! enforces the repo-specific unsafe/atomic/determinism invariants
//! catalogued in DESIGN.md §3.5 (S24). Zero dependencies; shared
//! verbatim between the `bleedlint` tool crate and the root package's
//! tier-1 `bleedlint_clean` integration test via `#[path]` inclusion.
//!
//! The analyzer is deliberately *lexical*: it scrubs comments and
//! string/char literals with a real tokenizer state machine (nested
//! block comments, raw strings, byte strings, lifetime-vs-char-literal
//! disambiguation), tracks brace depth to skip `#[cfg(test)]` modules,
//! and resolves "is there a contract comment for this site?" with a
//! statement-aware upward scan — but it does not type-check. Where a
//! lint needs type information it cannot have (L4's float folds, L5's
//! hash-container receivers), the heuristic is documented in the lint
//! catalog and pinned by fixture self-tests below; genuine false
//! positives are silenced in place with an audited
//! `// bleedlint: allow(Lx) -- reason` directive.

use std::fmt;
use std::path::Path;

// ---------------------------------------------------------------------
// Lint catalog
// ---------------------------------------------------------------------

/// The enforced lints. `L0` is the analyzer's own discipline check: a
/// malformed `bleedlint:` directive (e.g. an `allow` without a reason)
/// is itself a finding, so suppressions stay audited.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LintId {
    /// Malformed `bleedlint:` directive.
    L0,
    /// `unsafe` without a `// SAFETY:` / `# Safety` contract.
    L1,
    /// Atomic `Ordering::*` without an `// ORDER:` contract
    /// (`SeqCst` must additionally say why weaker orderings fail).
    L2,
    /// Thread spawning outside `util/pool.rs`.
    L3,
    /// Floating-point `.sum()`/`.fold(...)` reduction outside the
    /// documented fixed-fold kernels.
    L4,
    /// `HashMap`/`HashSet` iteration on a determinism/replay path.
    L5,
    /// Wall-clock reads inside the replay-deterministic session path
    /// outside `util/timer.rs`.
    L6,
}

pub const ALL_LINTS: [LintId; 7] = [
    LintId::L0,
    LintId::L1,
    LintId::L2,
    LintId::L3,
    LintId::L4,
    LintId::L5,
    LintId::L6,
];

impl LintId {
    pub fn code(self) -> &'static str {
        match self {
            LintId::L0 => "L0",
            LintId::L1 => "L1",
            LintId::L2 => "L2",
            LintId::L3 => "L3",
            LintId::L4 => "L4",
            LintId::L5 => "L5",
            LintId::L6 => "L6",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LintId::L0 => "malformed-directive",
            LintId::L1 => "unsafe-needs-safety-contract",
            LintId::L2 => "atomic-needs-order-contract",
            LintId::L3 => "thread-spawn-outside-pool",
            LintId::L4 => "float-fold-outside-kernels",
            LintId::L5 => "hash-iteration-on-deterministic-path",
            LintId::L6 => "wall-clock-outside-timer",
        }
    }

    /// One-line statement of the invariant, printed by `--list`.
    pub fn contract(self) -> &'static str {
        match self {
            LintId::L0 => "`// bleedlint: allow(Lx) -- reason` is the only accepted directive form; the reason is mandatory",
            LintId::L1 => "every `unsafe` block/fn/impl carries a `// SAFETY:` comment (or a `# Safety` doc section) stating the invariant that makes it sound",
            LintId::L2 => "every atomic `Ordering::*` use carries an `// ORDER:` contract; `SeqCst` must name why a weaker ordering is insufficient; orderings stay fully qualified so the lint can see them",
            LintId::L3 => "no `thread::spawn`/`thread::Builder`/`thread::scope` outside util/pool.rs — all parallelism goes through the pool's budgeted worker set",
            LintId::L4 => "no floating-point `.sum()`/`.fold(float-init, ..)` reductions outside util/simd.rs, util/stats.rs and linalg/ (NUMERICS.md fixed-fold contract); min/max lattice folds are exempt (order-insensitive)",
            LintId::L5 => "no HashMap/HashSet iteration feeding engine schedules, checkpoints or report output (coordinator/, metrics/, runtime/, cli/) — determinism paths iterate sorted or Vec-ordered",
            LintId::L6 => "no `Instant::now`/`SystemTime` reads inside the replay-deterministic session path (coordinator/, model/, linalg/, simulate/) except via util/timer.rs",
        }
    }

    pub fn parse(s: &str) -> Option<LintId> {
        ALL_LINTS.iter().copied().find(|l| l.code() == s)
    }
}

impl fmt::Display for LintId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} ({})", self.code(), self.name())
    }
}

/// One lint violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub lint: LintId,
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
    /// The offending source line, trimmed.
    pub snippet: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}: {}\n  | {}",
            self.path, self.line, self.lint, self.message, self.snippet
        )
    }
}

// ---------------------------------------------------------------------
// Lexer: scrub comments and literals, keep per-line code + comment text
// ---------------------------------------------------------------------

/// A source file after lexical scrubbing. `code[i]` holds line `i`'s
/// characters outside comments and outside string/char literal bodies
/// (delimiters are kept so tokens stay separated); `comment[i]` holds
/// the line's comment text (line, block and doc comments alike).
struct Scrubbed {
    code: Vec<String>,
    comment: Vec<String>,
    /// Line participates in a `#[...]`/`#![...]` attribute.
    attr: Vec<bool>,
    /// Line is inside a `#[cfg(test)] mod` body (lints skip it).
    test: Vec<bool>,
    /// Lints explicitly allowed for this line via a directive.
    allowed: Vec<Vec<LintId>>,
    /// Malformed-directive findings discovered while parsing allows.
    directive_findings: Vec<(usize, String, String)>,
}

#[derive(PartialEq)]
enum Mode {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
    CharLit,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn scrub(text: &str) -> Scrubbed {
    let b: Vec<char> = text.chars().collect();
    let mut code_lines: Vec<String> = Vec::new();
    let mut comment_lines: Vec<String> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut comment));
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = b.get(i + 1).copied();
                let prev_ident = i > 0 && is_ident(b[i - 1]);
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if !prev_ident && (c == 'r' || c == 'b') {
                    // Raw / byte string starts: r" r#" br" b" b' …
                    let mut j = i + 1;
                    let mut is_raw = c == 'r';
                    if c == 'b' {
                        match b.get(j) {
                            Some('r') => {
                                is_raw = true;
                                j += 1;
                            }
                            Some('"') => {
                                code.push('"');
                                mode = Mode::Str;
                                i = j + 1;
                                continue;
                            }
                            Some('\'') => {
                                code.push_str("''");
                                mode = Mode::CharLit;
                                i = j + 1;
                                continue;
                            }
                            _ => {
                                code.push(c);
                                i += 1;
                                continue;
                            }
                        }
                    }
                    if is_raw {
                        let mut hashes = 0usize;
                        while b.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if b.get(j) == Some(&'"') {
                            code.push('"');
                            mode = Mode::RawStr(hashes);
                            i = j + 1;
                            continue;
                        }
                    }
                    code.push(c);
                    i += 1;
                } else if c == '\'' {
                    // Lifetime or char literal. `'\…'` and `'x'` are
                    // literals; `'ident` (no closing quote right after
                    // one scalar) is a lifetime.
                    if next == Some('\\') {
                        code.push_str("''");
                        mode = Mode::CharLit;
                        i += 1;
                    } else if next.is_some() && b.get(i + 2) == Some(&'\'') {
                        code.push_str("''");
                        i += 3;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    mode = if depth == 1 {
                        Mode::Code
                    } else {
                        Mode::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let closed = (1..=hashes).all(|h| b.get(i + h) == Some(&'#'));
                    if closed {
                        code.push('"');
                        mode = Mode::Code;
                        i += 1 + hashes;
                        continue;
                    }
                }
                i += 1;
            }
            Mode::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() || text.ends_with('\n') {
        code_lines.push(code);
        comment_lines.push(comment);
    }

    let n = code_lines.len();
    let attr = mark_attr_lines(&code_lines);
    let test = mark_test_lines(&code_lines, &attr);
    let (allowed, directive_findings) = parse_allows(&code_lines, &comment_lines);
    debug_assert_eq!(comment_lines.len(), n);
    Scrubbed {
        code: code_lines,
        comment: comment_lines,
        attr,
        test,
        allowed,
        directive_findings,
    }
}

/// Mark lines participating in `#[...]` / `#![...]` attributes,
/// including multi-line attributes (tracked by `[`/`]` balance).
fn mark_attr_lines(code: &[String]) -> Vec<bool> {
    let mut attr = vec![false; code.len()];
    let mut balance = 0i64;
    let mut open = false;
    for (i, line) in code.iter().enumerate() {
        let t = line.trim_start();
        if !open && (t.starts_with("#[") || t.starts_with("#![")) {
            open = true;
            balance = 0;
        }
        if open {
            attr[i] = true;
            for c in line.chars() {
                match c {
                    '[' => balance += 1,
                    ']' => balance -= 1,
                    _ => {}
                }
            }
            if balance <= 0 {
                open = false;
            }
        }
    }
    attr
}

/// Mark lines inside `#[cfg(test)] mod … { … }` bodies using brace
/// depth over scrubbed code. Lints skip test modules: their invariants
/// are exercised dynamically (Miri/TSan run the same tests), and test
/// scaffolding legitimately spawns threads and reads clocks.
fn mark_test_lines(code: &[String], attr: &[bool]) -> Vec<bool> {
    let mut test = vec![false; code.len()];
    let mut depth = 0i64;
    let mut pending_cfg_test = false;
    let mut in_test_until_depth: Option<i64> = None;
    for (i, line) in code.iter().enumerate() {
        let start_depth = depth;
        for c in line.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        if let Some(d) = in_test_until_depth {
            test[i] = true;
            if depth <= d {
                in_test_until_depth = None;
            }
            continue;
        }
        let t = line.trim();
        if attr[i] && t.contains("cfg(test)") {
            pending_cfg_test = true;
            continue;
        }
        if pending_cfg_test {
            if t.is_empty() || attr[i] {
                continue;
            }
            if t.starts_with("mod ") || t.starts_with("pub mod ") {
                pending_cfg_test = false;
                if t.contains('{') && depth > start_depth {
                    test[i] = true;
                    in_test_until_depth = Some(start_depth);
                } else if !t.ends_with(';') {
                    // `mod x` with `{` on a later line.
                    test[i] = true;
                    in_test_until_depth = Some(start_depth);
                }
            } else {
                // `#[cfg(test)]` gating a non-module item (use, fn):
                // skip just that item's line.
                test[i] = true;
                pending_cfg_test = false;
            }
        }
    }
    test
}

/// Parse `bleedlint: allow(Lx[, Ly]) -- reason` directives out of the
/// comment text. A directive on a line with code covers that line; on a
/// comment-only line it covers the next line that has code.
fn parse_allows(
    code: &[String],
    comment: &[String],
) -> (Vec<Vec<LintId>>, Vec<(usize, String, String)>) {
    let mut allowed: Vec<Vec<LintId>> = vec![Vec::new(); code.len()];
    let mut malformed: Vec<(usize, String, String)> = Vec::new();
    for i in 0..code.len() {
        let c = &comment[i];
        let Some(pos) = c.find("bleedlint:") else {
            continue;
        };
        let rest = c[pos + "bleedlint:".len()..].trim_start();
        let parsed = parse_allow_body(rest);
        match parsed {
            Ok(ids) => {
                // Attach to this line if it has code, else to the next
                // code-bearing line.
                let mut target = i;
                if code[i].trim().is_empty() {
                    for (j, cj) in code.iter().enumerate().skip(i + 1) {
                        if !cj.trim().is_empty() {
                            target = j;
                            break;
                        }
                    }
                }
                allowed[target].extend(ids);
            }
            Err(why) => {
                malformed.push((i + 1, why, c.trim().to_string()));
            }
        }
    }
    (allowed, malformed)
}

/// Parse the body after `bleedlint:`. Accepted form:
/// `allow(L4) -- reason text` / `allow(L2, L5) -- reason`.
fn parse_allow_body(rest: &str) -> Result<Vec<LintId>, String> {
    let Some(args) = rest.strip_prefix("allow(") else {
        return Err("unknown directive (only `allow(Lx) -- reason` is supported)".into());
    };
    let Some(close) = args.find(')') else {
        return Err("unclosed `allow(` argument list".into());
    };
    let mut ids = Vec::new();
    for raw in args[..close].split(',') {
        let id = raw.trim();
        match LintId::parse(id) {
            Some(l) => ids.push(l),
            None => return Err(format!("unknown lint id `{id}` in allow(..)")),
        }
    }
    if ids.is_empty() {
        return Err("empty allow(..) list".into());
    }
    let tail = args[close + 1..].trim_start();
    let reason = tail.strip_prefix("--").map(str::trim).unwrap_or("");
    if reason.is_empty() {
        return Err("allow(..) without a `-- reason` justification".into());
    }
    Ok(ids)
}

// ---------------------------------------------------------------------
// Contract lookup (SAFETY / ORDER comments)
// ---------------------------------------------------------------------

impl Scrubbed {
    /// First line (0-based) of the statement containing line `ix`:
    /// walk up while the previous line carries code that does not end a
    /// statement/block (`;`, `{`, `}`); attribute lines are transparent.
    fn stmt_start(&self, ix: usize) -> usize {
        let mut s = ix;
        for _ in 0..40 {
            if s == 0 {
                break;
            }
            let prev = s - 1;
            let pc = self.code[prev].trim();
            if pc.is_empty() {
                break;
            }
            if self.attr[prev] {
                s = prev;
                continue;
            }
            match pc.chars().last() {
                Some(';') | Some('{') | Some('}') => break,
                _ => s = prev,
            }
        }
        s
    }

    /// Whether `lint` is allowed at `ix` — directly, or anywhere in the
    /// enclosing multi-line statement (an `allow` above a statement
    /// covers the whole chain, not just its first line).
    fn allowed_at(&self, ix: usize, lint: LintId) -> bool {
        let start = self.stmt_start(ix);
        (start..=ix).any(|i| self.allowed[i].contains(&lint))
    }

    /// The statement containing line `ix` as a single string: trimmed
    /// code lines concatenated without separators, so method chains
    /// split across lines (`slots` / `.values()`) re-join for pattern
    /// matching.
    fn stmt_text(&self, ix: usize) -> String {
        let start = self.stmt_start(ix);
        let mut text = String::new();
        for i in start..=ix {
            text.push_str(self.code[i].trim());
        }
        text
    }

    /// All comment text that can justify a site at `ix` (0-based):
    /// the line's own comment, trailing comments of earlier lines of
    /// the same multi-line statement, and the contiguous comment /
    /// attribute block immediately above the statement. For L1,
    /// adjacent one-line `unsafe impl … {}` items are transparent so a
    /// single SAFETY block can cover a Send/Sync pair.
    fn contract_text(&self, ix: usize, through_unsafe_impl: bool) -> String {
        let mut text = String::new();
        text.push_str(&self.comment[ix]);
        // Phase 1: walk to the start of the statement (bounded).
        let mut s = ix;
        for _ in 0..40 {
            if s == 0 {
                break;
            }
            let prev = s - 1;
            let pc = self.code[prev].trim();
            if pc.is_empty() {
                break; // blank or comment-only line — statement starts here
            }
            if self.attr[prev] {
                s = prev;
                continue;
            }
            match pc.chars().last() {
                Some(';') | Some('{') | Some('}') => break,
                _ => {
                    text.push_str(&self.comment[prev]);
                    text.push(' ');
                    s = prev;
                }
            }
        }
        // Phase 2: contiguous comment/attr block above the statement.
        let mut p = s;
        for _ in 0..80 {
            if p == 0 {
                break;
            }
            let prev = p - 1;
            let pc = self.code[prev].trim();
            let has_comment = !self.comment[prev].trim().is_empty();
            let transparent_impl = through_unsafe_impl
                && pc.starts_with("unsafe impl")
                && pc.ends_with("{}");
            if (pc.is_empty() && has_comment) || self.attr[prev] || transparent_impl {
                text.push(' ');
                text.push_str(&self.comment[prev]);
                p = prev;
            } else {
                break;
            }
        }
        text
    }
}

// ---------------------------------------------------------------------
// The lint passes
// ---------------------------------------------------------------------

/// Paths where L4 float reductions are legal: the documented fixed-fold
/// kernels (NUMERICS.md) and the scalar stats helpers built on them.
fn l4_allowed(path: &str) -> bool {
    path.ends_with("util/simd.rs") || path.ends_with("util/stats.rs") || path.contains("linalg/")
}

/// Determinism/replay paths for L5 (schedules, checkpoints, reports).
fn l5_restricted(path: &str) -> bool {
    ["coordinator/", "metrics/", "runtime/", "cli/"]
        .iter()
        .any(|p| path.starts_with(p) || path.contains(&format!("src/{p}")))
}

/// Replay-deterministic session path for L6.
fn l6_restricted(path: &str) -> bool {
    ["coordinator/", "model/", "linalg/", "simulate/"]
        .iter()
        .any(|p| path.starts_with(p) || path.contains(&format!("src/{p}")))
}

const ATOMIC_ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Lint one already-read source file. `rel_path` uses `/` separators
/// and is relative to the scanned root (e.g. `coordinator/state.rs`).
pub fn lint_source(rel_path: &str, text: &str) -> Vec<Finding> {
    let sc = scrub(text);
    let raw_lines: Vec<&str> = text.lines().collect();
    let mut out: Vec<Finding> = Vec::new();
    let snippet = |ix: usize| raw_lines.get(ix).map_or(String::new(), |l| l.trim().to_string());
    let mut push = |lint: LintId, ix: usize, message: String, snip: String| {
        if !out.iter().any(|f: &Finding| f.lint == lint && f.line == ix + 1) {
            out.push(Finding {
                lint,
                path: rel_path.to_string(),
                line: ix + 1,
                message,
                snippet: snip,
            });
        }
    };

    // L0: malformed directives are findings wherever they appear.
    for (line, why, snip) in &sc.directive_findings {
        push(LintId::L0, line - 1, why.clone(), snip.clone());
    }

    // Names bound to hash containers in this file (L5 heuristic).
    let hash_names = harvest_hash_names(&sc);

    for ix in 0..sc.code.len() {
        if sc.test[ix] {
            continue;
        }
        let code = sc.code[ix].clone();
        let allowed = |l: LintId| sc.allowed_at(ix, l);

        // ---- L1: unsafe needs a SAFETY contract ----
        if !allowed(LintId::L1) && has_word(&code, "unsafe") {
            let contract = sc.contract_text(ix, true);
            if !contract.contains("SAFETY:") && !contract.contains("# Safety") {
                push(
                    LintId::L1,
                    ix,
                    "`unsafe` without a `// SAFETY:` (or `# Safety` doc) contract stating the \
                     invariant that makes it sound"
                        .into(),
                    snippet(ix),
                );
            }
        }

        // ---- L2: atomic orderings need an ORDER contract ----
        if !allowed(LintId::L2) {
            let trimmed = code.trim_start();
            if trimmed.starts_with("use ") && code.contains("atomic::Ordering::") {
                push(
                    LintId::L2,
                    ix,
                    "importing `Ordering` variants hides them from the lint; keep atomic \
                     orderings fully qualified (`Ordering::Relaxed`, …)"
                        .into(),
                    snippet(ix),
                );
            } else {
                for ord in ATOMIC_ORDERINGS {
                    if !code.contains(&format!("Ordering::{ord}")) {
                        continue;
                    }
                    let contract = sc.contract_text(ix, false);
                    if !contract.contains("ORDER:") {
                        push(
                            LintId::L2,
                            ix,
                            format!(
                                "atomic `Ordering::{ord}` without an `// ORDER:` contract \
                                 documenting the required happens-before (or why none is needed)"
                            ),
                            snippet(ix),
                        );
                    } else if ord == "SeqCst" && !contract.contains("SeqCst") {
                        push(
                            LintId::L2,
                            ix,
                            "`SeqCst` site: the `// ORDER:` contract must name why a weaker \
                             ordering (Acquire/Release/Relaxed) is insufficient — mention \
                             `SeqCst` explicitly"
                                .into(),
                            snippet(ix),
                        );
                    }
                }
            }
        }

        // ---- L3: thread spawning outside the pool ----
        if !allowed(LintId::L3) && !rel_path.ends_with("util/pool.rs") {
            for pat in ["thread::spawn", "thread::Builder", "thread::scope"] {
                if code.contains(pat) {
                    push(
                        LintId::L3,
                        ix,
                        format!(
                            "`{pat}` outside util/pool.rs: all parallelism must go through the \
                             pool's budgeted worker set (§3.2 two-level budget)"
                        ),
                        snippet(ix),
                    );
                }
            }
        }

        // ---- L4: float reductions outside the documented kernels ----
        if !allowed(LintId::L4) && !l4_allowed(rel_path) {
            if let Some(why) = float_fold_on_line(&sc, ix) {
                push(
                    LintId::L4,
                    ix,
                    format!(
                        "{why} outside the documented fixed-fold kernels (util/simd.rs, \
                         util/stats.rs, linalg/) — route through a documented fold or justify \
                         with `// bleedlint: allow(L4) -- reason` (NUMERICS.md)"
                    ),
                    snippet(ix),
                );
            }
        }

        // ---- L5: hash iteration on determinism paths ----
        if !allowed(LintId::L5) && l5_restricted(rel_path) {
            if let Some(name) = hash_iteration_at(&sc, ix, &hash_names) {
                push(
                    LintId::L5,
                    ix,
                    format!(
                        "iteration over hash container `{name}` on a determinism/replay path: \
                         hash order is nondeterministic — iterate a sorted Vec/BTreeMap, sort \
                         before use, or justify with `// bleedlint: allow(L5) -- reason`"
                    ),
                    snippet(ix),
                );
            }
        }

        // ---- L6: wall clock inside the session path ----
        if !allowed(LintId::L6) && l6_restricted(rel_path) && !rel_path.ends_with("util/timer.rs") {
            for pat in ["Instant::now", "SystemTime::now", "SystemTime::UNIX_EPOCH"] {
                if code.contains(pat) {
                    push(
                        LintId::L6,
                        ix,
                        format!(
                            "`{pat}` inside the replay-deterministic session path: read time \
                             through `util::timer::Stopwatch` (or a `Clock` impl) so replays \
                             and simulations stay deterministic"
                        ),
                        snippet(ix),
                    );
                }
            }
        }
    }
    out.sort_by(|a, b| (a.line, a.lint).cmp(&(b.line, b.lint)));
    out
}

/// Word-boundary containment check on scrubbed code.
fn has_word(code: &str, word: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident(code[..at].chars().next_back().unwrap());
        let after = code[at + word.len()..].chars().next();
        let after_ok = after.is_none_or(|c| !is_ident(c));
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// L4 detector: returns a description if line `ix` performs a
/// floating-point reduction. Rules (documented in the catalog):
/// * `.sum::<f64>()` / `.sum::<f32>()` always count;
/// * `.fold(` with a float initializer counts, except min/max lattice
///   folds (`f64::min` / `f64::max` etc.), which are order-insensitive;
/// * a bare `.sum()` counts when the enclosing statement mentions
///   `f64`/`f32` (lexical float-context heuristic).
fn float_fold_on_line(sc: &Scrubbed, ix: usize) -> Option<String> {
    let code = &sc.code[ix];
    if code.contains(".sum::<f64>") || code.contains(".sum::<f32>") {
        return Some("floating-point `.sum::<fN>()` reduction".into());
    }
    if let Some(pos) = code.find(".fold(") {
        let init = code[pos + ".fold(".len()..].trim_start();
        let is_float_init = init.starts_with("f64::")
            || init.starts_with("f32::")
            || looks_like_float_literal(init);
        let is_lattice = code.contains("::min") || code.contains("::max");
        if is_float_init && !is_lattice {
            return Some("floating-point `.fold(..)` reduction".into());
        }
    }
    if code.contains(".sum()") {
        // Collect the statement's code (this line plus up to 4
        // continuation lines above) and look for float context.
        let mut stmt = code.clone();
        let mut s = ix;
        for _ in 0..4 {
            if s == 0 {
                break;
            }
            let prev = s - 1;
            let pc = sc.code[prev].trim();
            if pc.is_empty() || matches!(pc.chars().last(), Some(';') | Some('{') | Some('}')) {
                break;
            }
            stmt.push(' ');
            stmt.push_str(pc);
            s = prev;
        }
        if stmt.contains("f64") || stmt.contains("f32") {
            return Some("floating-point `.sum()` reduction (float-typed statement)".into());
        }
    }
    None
}

fn looks_like_float_literal(s: &str) -> bool {
    let mut chars = s.chars().peekable();
    let mut saw_digit = false;
    while let Some(&c) = chars.peek() {
        if c.is_ascii_digit() || c == '_' {
            saw_digit = true;
            chars.next();
        } else {
            break;
        }
    }
    if !saw_digit {
        return false;
    }
    match chars.next() {
        Some('.') => true,
        Some('f') => {
            let rest: String = chars.collect();
            rest.starts_with("32") || rest.starts_with("64")
        }
        _ => false,
    }
}

/// Harvest identifiers bound to `HashMap`/`HashSet` in this file
/// (let bindings, struct fields, fn params — lexical heuristic).
fn harvest_hash_names(sc: &Scrubbed) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for code in &sc.code {
        for token in ["HashMap", "HashSet"] {
            let mut start = 0usize;
            while let Some(pos) = code[start..].find(token) {
                let at = start + pos;
                start = at + token.len();
                // Reject matches inside longer identifiers.
                if at > 0 && is_ident(code[..at].chars().next_back().unwrap()) {
                    continue;
                }
                // `::` path segments obscure the separator search:
                // neutralize them, then find the nearest `:` or `=`
                // to the left — the identifier before it is the binding.
                let left = code[..at].replace("::", "  ");
                let sep = left.rfind([':', '=']);
                let Some(sep) = sep else { continue };
                let ident: String = left[..sep]
                    .trim_end()
                    .chars()
                    .rev()
                    .take_while(|&c| is_ident(c))
                    .collect::<String>()
                    .chars()
                    .rev()
                    .collect();
                if !ident.is_empty()
                    && !ident.chars().next().unwrap().is_ascii_digit()
                    && ident != "mut"
                    && !names.contains(&ident)
                {
                    names.push(ident);
                }
            }
        }
    }
    names
}

const HASH_ITER_METHODS: [&str; 7] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".drain(",
];

/// L5 detector, anchored at line `ix`: the line must carry an iteration
/// method (or a `for … in` header), and the *statement* — chain lines
/// re-joined, so `slots` / `.values()` splits don't hide the receiver —
/// must apply it to one of the harvested hash-container `names`.
fn hash_iteration_at(sc: &Scrubbed, ix: usize, names: &[String]) -> Option<String> {
    let line = &sc.code[ix];
    let line_has_method =
        HASH_ITER_METHODS.iter().any(|m| line.contains(m)) || line.contains("for ");
    if !line_has_method {
        return None;
    }
    let stmt = sc.stmt_text(ix);
    for name in names {
        for method in HASH_ITER_METHODS {
            let pat = format!("{name}{method}");
            if line.contains(method) {
                if let Some(pos) = stmt.find(&pat) {
                    let before_ok =
                        pos == 0 || !is_ident(stmt[..pos].chars().next_back().unwrap());
                    if before_ok {
                        return Some(name.clone());
                    }
                }
            }
        }
        // `for x in &name` / `for x in name` loop headers (single-line).
        if line.contains("for ") {
            for pat in [format!("in &{name}"), format!("in {name}")] {
                if let Some(pos) = line.find(&pat) {
                    let after = line[pos + pat.len()..].chars().next();
                    if after.is_none_or(|c| !is_ident(c) && c != '.' && c != '(') {
                        return Some(name.clone());
                    }
                }
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// Tree walking
// ---------------------------------------------------------------------

/// Lint every `.rs` file under `root` (sorted traversal, so output
/// order is deterministic — the same discipline L5 enforces).
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>, String> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let text = std::fs::read_to_string(f)
            .map_err(|e| format!("read {}: {e}", f.display()))?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(lint_source(&rel, &text));
    }
    Ok(out)
}

/// Number of `.rs` files a [`lint_tree`] call over `root` would scan.
pub fn count_rs_files(root: &Path) -> Result<usize, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    Ok(files.len())
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut entries: Vec<_> = entries
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Fixture self-tests: every lint both ways (flagged / clean), plus the
// lexer's tricky cases (literals, comments, attributes, test modules).
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(findings: &[Finding]) -> Vec<(&'static str, usize)> {
        findings.iter().map(|f| (f.lint.code(), f.line)).collect()
    }

    // ---- L1 ----

    #[test]
    fn l1_flags_uncommented_unsafe() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(codes(&lint_source("util/x.rs", bad)), vec![("L1", 2)]);
    }

    #[test]
    fn l1_accepts_safety_comment_and_doc_section() {
        let good = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
        let doc = "/// Reads a byte.\n///\n/// # Safety\n/// `p` must be valid.\npub unsafe fn f(p: *const u8) -> u8 {\n    *p\n}\n";
        assert!(lint_source("util/x.rs", doc).is_empty());
    }

    #[test]
    fn l1_safety_block_covers_send_sync_pair() {
        let good = "struct W(*mut u8);\n\n// SAFETY: access is serialized by the owning mutex.\nunsafe impl Send for W {}\nunsafe impl Sync for W {}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
        // Without the comment, both impls flag.
        let bad = "struct W(*mut u8);\n\nunsafe impl Send for W {}\nunsafe impl Sync for W {}\n";
        assert_eq!(codes(&lint_source("util/x.rs", bad)), vec![("L1", 3), ("L1", 4)]);
    }

    #[test]
    fn l1_ignores_unsafe_in_strings_and_comments() {
        let good = "// this mentions unsafe code in prose\nfn f() -> &'static str {\n    \"unsafe { }\"\n}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
        let raw = "fn f() -> &'static str {\n    r#\"unsafe impl Send for X {}\"#\n}\n";
        assert!(lint_source("util/x.rs", raw).is_empty());
    }

    #[test]
    fn l1_survives_multiline_attribute() {
        let good = "#[cfg(\n    target_arch = \"x86_64\"\n)]\n// SAFETY: caller verified AVX2.\nunsafe fn g() {}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
        let bad = "#[cfg(\n    target_arch = \"x86_64\"\n)]\nunsafe fn g() {}\n";
        assert_eq!(codes(&lint_source("util/x.rs", bad)), vec![("L1", 4)]);
    }

    #[test]
    fn l1_trailing_comment_on_statement_counts() {
        let good = "fn f(p: *const u8) -> u8 {\n    let v = // SAFETY: p valid per contract.\n        unsafe { *p };\n    v\n}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
    }

    // ---- L2 ----

    #[test]
    fn l2_flags_undocumented_ordering() {
        let bad = "fn f(a: &std::sync::atomic::AtomicU64) -> u64 {\n    a.load(Ordering::Relaxed)\n}\n";
        assert_eq!(codes(&lint_source("util/x.rs", bad)), vec![("L2", 2)]);
    }

    #[test]
    fn l2_accepts_order_contract() {
        let good = "fn f(a: &AtomicU64) -> u64 {\n    // ORDER: independent counter; no data published through it.\n    a.load(Ordering::Relaxed)\n}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
    }

    #[test]
    fn l2_seqcst_must_name_why_weaker_fails() {
        let vague = "fn f(a: &AtomicU64) -> u64 {\n    // ORDER: synchronizes stuff.\n    a.load(Ordering::SeqCst)\n}\n";
        let f = lint_source("util/x.rs", vague);
        assert_eq!(codes(&f), vec![("L2", 3)]);
        assert!(f[0].message.contains("SeqCst"));
        let good = "fn f(a: &AtomicU64) -> u64 {\n    // ORDER: SeqCst — needs a single total order across this flag\n    // and the queue cursor; Acquire/Release on each alone allows the\n    // IRIW interleaving that loses a wakeup.\n    a.load(Ordering::SeqCst)\n}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
    }

    #[test]
    fn l2_flags_variant_imports() {
        let bad = "use std::sync::atomic::Ordering::Relaxed;\n";
        assert_eq!(codes(&lint_source("util/x.rs", bad)), vec![("L2", 1)]);
    }

    #[test]
    fn l2_contract_covers_multiline_call() {
        let good = "fn f(a: &AtomicU64) {\n    // ORDER: slot reservation needs only RMW atomicity.\n    let _ = a.compare_exchange_weak(\n        0,\n        1,\n        Ordering::Relaxed,\n        Ordering::Relaxed,\n    );\n}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
    }

    #[test]
    fn l2_ignores_cmp_ordering() {
        let good = "fn f(xs: &mut [f64]) {\n    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));\n}\n";
        assert!(lint_source("util/x.rs", good).is_empty());
    }

    // ---- L3 ----

    #[test]
    fn l3_flags_spawn_outside_pool() {
        let bad = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
        assert_eq!(codes(&lint_source("coordinator/x.rs", bad)), vec![("L3", 2)]);
        let builder = "fn f() {\n    std::thread::Builder::new().spawn(|| {}).unwrap();\n}\n";
        assert_eq!(codes(&lint_source("model/x.rs", builder)), vec![("L3", 2)]);
    }

    #[test]
    fn l3_allows_pool_and_tests() {
        let pool = "fn f() {\n    std::thread::Builder::new().spawn(|| {}).unwrap();\n}\n";
        assert!(lint_source("util/pool.rs", pool).is_empty());
        let test = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        std::thread::scope(|s| { s.spawn(|| {}); });\n    }\n}\n";
        assert!(lint_source("coordinator/x.rs", test).is_empty());
    }

    // ---- L4 ----

    #[test]
    fn l4_flags_float_sum_outside_kernels() {
        let bad = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>()\n}\n";
        assert_eq!(codes(&lint_source("coordinator/x.rs", bad)), vec![("L4", 2)]);
        // Same code inside linalg/ or util/stats.rs is the documented home.
        assert!(lint_source("linalg/scores.rs", bad).is_empty());
        assert!(lint_source("util/stats.rs", bad).is_empty());
    }

    #[test]
    fn l4_flags_bare_sum_with_float_context() {
        let bad = "fn f(p: &[f32]) -> f64 {\n    let d: f64 = p\n        .iter()\n        .map(|&x| x as f64)\n        .sum();\n    d\n}\n";
        assert_eq!(codes(&lint_source("data/x.rs", bad)), vec![("L4", 5)]);
        let int = "fn f(xs: &[u64]) -> u64 {\n    xs.iter().sum()\n}\n";
        assert!(lint_source("data/x.rs", int).is_empty());
    }

    #[test]
    fn l4_exempts_lattice_folds() {
        let good = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().copied().fold(f64::INFINITY, f64::min)\n}\n";
        assert!(lint_source("coordinator/x.rs", good).is_empty());
        let bad = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().fold(0.0, |a, &b| a + b)\n}\n";
        assert_eq!(codes(&lint_source("coordinator/x.rs", bad)), vec![("L4", 2)]);
    }

    // ---- L5 ----

    #[test]
    fn l5_flags_hash_iteration_on_restricted_paths() {
        let bad = "use std::collections::HashMap;\nfn f(slots: &HashMap<u32, f64>) -> Vec<f64> {\n    slots.values().copied().collect()\n}\n";
        assert_eq!(codes(&lint_source("coordinator/cache.rs", bad)), vec![("L5", 3)]);
        // The same code outside the determinism paths is fine.
        assert!(lint_source("data/x.rs", bad).is_empty());
    }

    #[test]
    fn l5_flags_for_loops_and_lets() {
        let bad = "fn f() {\n    let mut seen = std::collections::HashMap::new();\n    seen.insert(1u32, 2u32);\n    for (k, v) in &seen {\n        let _ = (k, v);\n    }\n}\n";
        assert_eq!(codes(&lint_source("metrics/x.rs", bad)), vec![("L5", 4)]);
    }

    #[test]
    fn l5_sees_through_multiline_chains() {
        // The receiver and the method live on different lines; the
        // statement-joined view still connects `slots` to `.values()`.
        let bad = "use std::collections::HashMap;\nfn f(slots: &HashMap<u32, f64>) -> Vec<f64> {\n    let out: Vec<f64> = slots\n        .values()\n        .copied()\n        .collect();\n    out\n}\n";
        assert_eq!(codes(&lint_source("coordinator/cache.rs", bad)), vec![("L5", 4)]);
        // An allow above the statement covers the whole chain, even
        // though the finding anchors on a deeper line.
        let ok = "use std::collections::HashMap;\nfn f(slots: &HashMap<u32, f64>) -> Vec<f64> {\n    // bleedlint: allow(L5) -- sorted before any caller sees it\n    let mut out: Vec<f64> = slots\n        .values()\n        .copied()\n        .collect();\n    out.sort_by(|a, b| a.total_cmp(b));\n    out\n}\n";
        assert!(lint_source("coordinator/cache.rs", ok).is_empty());
    }

    #[test]
    fn l5_allows_lookups_and_vec_iteration() {
        let good = "fn f(counts: &std::collections::HashMap<usize, usize>, ks: &[usize]) -> usize {\n    ks.iter().map(|k| counts[k]).sum()\n}\n";
        assert!(lint_source("coordinator/x.rs", good).is_empty());
    }

    // ---- L6 ----

    #[test]
    fn l6_flags_wall_clock_in_session_path() {
        let bad = "fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
        assert_eq!(codes(&lint_source("coordinator/session.rs", bad)), vec![("L6", 2)]);
        assert_eq!(codes(&lint_source("model/kmeans.rs", bad)), vec![("L6", 2)]);
        // The CLI/bench layers report wall time by design.
        assert!(lint_source("cli/mod.rs", bad).is_empty());
        assert!(lint_source("bench/mod.rs", bad).is_empty());
        // util/timer.rs is the sanctioned wrapper.
        assert!(lint_source("util/timer.rs", bad).is_empty());
    }

    // ---- allow directives ----

    #[test]
    fn allow_suppresses_named_lint_only() {
        let allowed = "fn f(xs: &[f64]) -> f64 {\n    // bleedlint: allow(L4) -- generator-side fold, fixed order by construction\n    xs.iter().sum::<f64>()\n}\n";
        assert!(lint_source("data/x.rs", allowed).is_empty());
        // The allow names L4; an L2 violation on the same line still fires.
        let wrong = "fn f(a: &AtomicU64) -> u64 {\n    // bleedlint: allow(L4) -- not the right lint\n    a.load(Ordering::Relaxed)\n}\n";
        assert_eq!(codes(&lint_source("util/x.rs", wrong)), vec![("L2", 3)]);
    }

    #[test]
    fn allow_on_same_line_works() {
        let s = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>() // bleedlint: allow(L4) -- documented caller-side mean\n}\n";
        assert!(lint_source("data/x.rs", s).is_empty());
    }

    #[test]
    fn allow_without_reason_is_a_finding() {
        let s = "fn f(xs: &[f64]) -> f64 {\n    // bleedlint: allow(L4)\n    xs.iter().sum::<f64>()\n}\n";
        let f = lint_source("data/x.rs", s);
        // Both the malformed directive AND the undischarged L4 fire.
        assert_eq!(codes(&f), vec![("L0", 2), ("L4", 3)]);
    }

    #[test]
    fn allow_with_unknown_id_is_a_finding() {
        let s = "// bleedlint: allow(L9) -- no such lint\nfn f() {}\n";
        assert_eq!(codes(&lint_source("util/x.rs", s)), vec![("L0", 1)]);
    }

    #[test]
    fn allow_list_covers_multiple_lints() {
        let s = "fn f(m: &std::collections::HashMap<u32, f64>) -> f64 {\n    // bleedlint: allow(L4, L5) -- commutative sum over values for a gauge metric\n    m.values().sum::<f64>()\n}\n";
        assert!(lint_source("metrics/x.rs", s).is_empty());
    }

    // ---- lexer edge cases ----

    #[test]
    fn lexer_handles_lifetimes_chars_and_raw_strings() {
        let s = "fn f<'a>(x: &'a str) -> char {\n    let q = '\"';\n    let _r = r#\"Ordering::SeqCst unsafe\"#;\n    let _e = '\\'';\n    let _ = x;\n    q\n}\nfn g(a: &AtomicU64) -> u64 {\n    a.load(Ordering::Relaxed)\n}\n";
        // The only finding is the genuinely-undocumented Relaxed in g():
        // nothing in the string/char soup confused the lexer.
        assert_eq!(codes(&lint_source("util/x.rs", s)), vec![("L2", 9)]);
    }

    #[test]
    fn lexer_handles_nested_block_comments() {
        let s = "/* outer /* inner unsafe Ordering::SeqCst */ still comment */\nfn f() {}\n";
        assert!(lint_source("util/x.rs", s).is_empty());
    }

    #[test]
    fn lexer_handles_byte_literals() {
        let s = "fn f() -> (u8, &'static [u8]) {\n    (b'x', b\"unsafe\")\n}\n";
        assert!(lint_source("util/x.rs", s).is_empty());
    }

    #[test]
    fn test_modules_are_skipped_entirely() {
        let s = "fn prod(a: &AtomicU64) -> u64 {\n    // ORDER: independent counter.\n    a.load(Ordering::Relaxed)\n}\n\n#[cfg(test)]\nmod tests {\n    use super::*;\n\n    #[test]\n    fn t() {\n        let a = AtomicU64::new(0);\n        a.store(1, Ordering::SeqCst);\n        let _ = unsafe { *(&1u8 as *const u8) };\n    }\n}\n";
        assert!(lint_source("coordinator/x.rs", s).is_empty());
    }

    #[test]
    fn code_after_test_module_is_linted_again() {
        let s = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {}\n}\n\nfn late(a: &AtomicU64) -> u64 {\n    a.load(Ordering::Relaxed)\n}\n";
        assert_eq!(codes(&lint_source("util/x.rs", s)), vec![("L2", 8)]);
    }

    // ---- catalog sanity ----

    #[test]
    fn every_lint_has_code_name_contract() {
        for l in ALL_LINTS {
            assert!(!l.code().is_empty());
            assert!(!l.name().is_empty());
            assert!(!l.contract().is_empty());
            assert_eq!(LintId::parse(l.code()), Some(l));
        }
        assert_eq!(LintId::parse("L99"), None);
    }

    #[test]
    fn findings_render_with_location_and_snippet() {
        let bad = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
        let f = lint_source("coordinator/x.rs", bad);
        let shown = f[0].to_string();
        assert!(shown.contains("coordinator/x.rs:2"));
        assert!(shown.contains("L3"));
        assert!(shown.contains("thread::spawn"));
    }
}
