//! `bleedlint` — the repo's in-tree static analysis pass for the
//! unsafe / atomic / determinism surface. See DESIGN.md §3.5 (S24) for
//! the lint catalog and the `// bleedlint: allow(Lx) -- reason`
//! exception syntax.
//!
//! The analyzer lives in [`analyzer`] as a single self-contained file
//! so the root package's tier-1 `bleedlint_clean` test can include it
//! with `#[path]` without a cross-crate dev-dependency (the repo's
//! default build stays a single zero-dependency package).

pub mod analyzer;

pub use analyzer::{count_rs_files, lint_source, lint_tree, Finding, LintId, ALL_LINTS};
