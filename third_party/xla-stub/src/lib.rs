//! Compile-only stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The sandbox that builds this repository has no network access and no
//! XLA toolchain, so the real bindings cannot be vendored. This crate
//! mirrors exactly the API surface `binary_bleed`'s `pjrt` feature uses —
//! enough for `cargo check --features pjrt` to validate the runtime layer
//! offline. Every operation returns [`Error::Unavailable`] at runtime.
//!
//! On a machine with the XLA toolchain, point the `xla` dependency in the
//! workspace `Cargo.toml` at the real xla-rs checkout instead; the
//! `binary_bleed::runtime` code compiles unchanged against both.

use std::fmt;

/// Stub error: every entry point reports the runtime is unavailable.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real XLA/PJRT toolchain \
                 (this build uses the compile-only stub)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Host-side literal (tensor value).
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Copy out as a flat host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        0
    }

    /// Unpack a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer contents as a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO *text* file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable loaded on a PJRT client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; returns per-device output buffers.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}
